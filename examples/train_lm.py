"""Train a language model end-to-end for a few hundred steps on the
synthetic bigram stream via the production train_step (grad accumulation,
mixed precision, checkpointing) and verify the loss drops.

    PYTHONPATH=src python examples/train_lm.py [--size 25m|100m] [--steps 150]

25m (default) fits the CPU container's step budget; 100m is the same code
at the deliverable's reference size for real hardware.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import token_batch_iterator
from repro.launch import steps as S
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.utils import tree_size

SIZES = {
    "25m": ModelConfig("lm-25m", "dense", n_layers=6, d_model=384,
                       n_heads=6, n_kv_heads=2, d_ff=1536, vocab_size=8192,
                       dtype="float32", microbatches=2),
    "100m": ModelConfig("lm-100m", "dense", n_layers=12, d_model=768,
                        n_heads=12, n_kv_heads=4, d_ff=3072,
                        vocab_size=32768, dtype="float32", microbatches=2),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="25m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = SIZES[args.size]
    mesh = make_debug_mesh()
    with mesh:
        params = T.init(jax.random.PRNGKey(0), cfg)
        print(f"{cfg.name}: {tree_size(params)/1e6:.1f}M params")
        step_fn, opt = S.make_train_step(cfg, mesh, lr=3e-3)
        opt_state = opt.init(params)
        step_j = jax.jit(step_fn, donate_argnums=(0, 1))
        it = token_batch_iterator(cfg.vocab_size, args.batch, args.seq, seed=0)
        losses = []
        t0 = time.time()
        for i in range(1, args.steps + 1):
            raw = next(it)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt_state, m = step_j(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if i % 10 == 0:
                print(f"step {i:4d} loss={losses[-1]:.4f} "
                      f"({(time.time()-t0)/i:.2f}s/step)", flush=True)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.3 else 'no progress?'})")


if __name__ == "__main__":
    main()
