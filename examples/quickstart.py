"""Quickstart: the paper's full pipeline on a pocket-sized world.

    PYTHONPATH=src python examples/quickstart.py [--smoke]

1. builds a synthetic non-IID federated dataset (40 IoT devices),
2. clusters devices with the IKC mini model (Algorithm 2),
3. schedules a cohort (Algorithm 4), assigns it to edge servers,
4. allocates bandwidth/CPU (problem 27), prices the round (eqs. 4-14),
5. runs a few HFL global iterations (Algorithm 1) and prints accuracy.

``--smoke`` shrinks the world to CI-guard size (the examples-smoke job
runs it on every push: the point is that the public entry points still
execute, not the accuracy it reaches).
"""
import argparse
import time


from repro.core.cost_model import SystemParams, sample_population
from repro.core.framework import FrameworkConfig, HFLFramework
from repro.data import make_dataset, partition_noniid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny world / 2 rounds (CI smoke)")
    args = ap.parse_args()
    t0 = time.time()
    n_dev = 12 if args.smoke else 40
    sp = SystemParams(n_devices=n_dev, n_edges=5, d_range=(50, 90))
    pop = sample_population(sp, seed=0)
    n_train, n_test = (600, 150) if args.smoke else (5000, 800)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                n_test=n_test, seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_dev,
                           size_range=(20, 40) if args.smoke else (50, 90),
                           seed=0)
    print(f"[{time.time()-t0:5.1f}s] world ready: {fed.n_devices} devices, "
          f"{sp.n_edges} edges")

    cfg = FrameworkConfig(scheduler="ikc", assigner="geo",
                          H=6 if args.smoke else 20, K=4 if args.smoke else 10,
                          target_acc=0.70, max_iters=2 if args.smoke else 6,
                          seed=0)
    fw = HFLFramework(sp, pop, fed, cfg)
    cs = fw.clustering_stats
    print(f"[{time.time()-t0:5.1f}s] IKC clustering: ARI={cs['ari']:.2f} "
          f"delay={cs['delay_s']:.1f}s energy={cs['energy_j']:.1f}J "
          f"(mini model {cs['aux_bits']/8e3:.1f} KB)")

    summary = fw.run(verbose=True)
    print(f"[{time.time()-t0:5.1f}s] finished: {summary['iters']} rounds, "
          f"acc={summary['final_acc']:.3f}, E+λT={summary['objective']:.0f}")


if __name__ == "__main__":
    main()
