"""Quickstart: the paper's full pipeline on a pocket-sized world.

    PYTHONPATH=src python examples/quickstart.py

1. builds a synthetic non-IID federated dataset (40 IoT devices),
2. clusters devices with the IKC mini model (Algorithm 2),
3. schedules a cohort (Algorithm 4), assigns it to edge servers,
4. allocates bandwidth/CPU (problem 27), prices the round (eqs. 4-14),
5. runs a few HFL global iterations (Algorithm 1) and prints accuracy.
"""
import time


from repro.core.cost_model import SystemParams, sample_population
from repro.core.framework import FrameworkConfig, HFLFramework
from repro.data import make_dataset, partition_noniid


def main():
    t0 = time.time()
    sp = SystemParams(n_devices=40, n_edges=5, d_range=(50, 90))
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=5000, n_test=800,
                                seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=40, size_range=(50, 90),
                           seed=0)
    print(f"[{time.time()-t0:5.1f}s] world ready: {fed.n_devices} devices, "
          f"{sp.n_edges} edges")

    cfg = FrameworkConfig(scheduler="ikc", assigner="geo", H=20, K=10,
                          target_acc=0.70, max_iters=6, seed=0)
    fw = HFLFramework(sp, pop, fed, cfg)
    cs = fw.clustering_stats
    print(f"[{time.time()-t0:5.1f}s] IKC clustering: ARI={cs['ari']:.2f} "
          f"delay={cs['delay_s']:.1f}s energy={cs['energy_j']:.1f}J "
          f"(mini model {cs['aux_bits']/8e3:.1f} KB)")

    summary = fw.run(verbose=True)
    print(f"[{time.time()-t0:5.1f}s] finished: {summary['iters']} rounds, "
          f"acc={summary['final_acc']:.3f}, E+λT={summary['objective']:.0f}")


if __name__ == "__main__":
    main()
