"""Multi-config HFL launcher: arch x scheduler x codec sweep matrix.

maxtext-style job launcher over the model-zoo registry: one flat
``BASE_CONFIG`` dict, per-job override dicts validated against it
(unknown keys are an assert, not a silent typo), and a ``run_job``
that builds the world, resolves the arch through
``configs.registry.get_hfl_spec``, and drives one fused
``SweepRunner`` sweep. Every job appends a JSON line to
``results/model_zoo_runs.jsonl`` so a matrix of runs is one greppable
file.

    PYTHONPATH=src python examples/model_zoo_launcher.py            # full matrix
    PYTHONPATH=src python examples/model_zoo_launcher.py --smoke    # CI subset
    PYTHONPATH=src python examples/model_zoo_launcher.py --dryrun   # print jobs

The full matrix crosses every ``HFL_SMOKE_ARCHS`` payload (paper CNN,
dense transformer, SSM, MoE) with the paper's schedulers (FedAvg /
IKC) and the PR-9 uplink codecs (none / int8). ``--smoke`` runs one
job per arch family at tiny shapes — the examples-smoke CI lane.
"""
import argparse
import copy
import json
import os
import time

BASE_CONFIG = {
    "arch": "hfl-cnn",        # configs.registry payload id
    "scheduler": "fedavg",    # fedavg | ikc | vkc
    "codec": "none",          # none | bf16_delta | int8 | topk
    "assign": "geo",          # geo | mod | hfel
    "rounds": 6,
    "n_devices": 8,
    "n_edges": 2,
    "H": 4,
    "lr": 0.3,
    "n_train": 600,
    "n_test": 128,
    "alloc_steps": 25,
    "seed": 0,
}


def update_config_fields(base, updates, allow_new_keys=False):
    """Copy ``base`` with ``updates`` applied; unknown keys assert."""
    cfg = copy.deepcopy(base)
    for key, value in updates.items():
        if not allow_new_keys:
            assert key in cfg, f"unknown config key: {key!r}"
        cfg[key] = value
    return cfg


def _world(cfg):
    from repro.configs.registry import get_smoke_config
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_dataset, make_seq_dataset, partition_noniid

    sp = SystemParams(n_devices=cfg["n_devices"], n_edges=cfg["n_edges"],
                      d_range=(6, 10))
    pop = sample_population(sp, seed=cfg["seed"])
    if cfg["arch"] == "hfl-cnn":
        X, y, Xt, yt = make_dataset("fmnist_syn", n_train=cfg["n_train"],
                                    n_test=cfg["n_test"], seed=cfg["seed"])
    else:
        vocab = min(257, get_smoke_config(cfg["arch"]).vocab_size)
        X, y, Xt, yt = make_seq_dataset(n_train=cfg["n_train"],
                                        n_test=cfg["n_test"],
                                        seed=cfg["seed"],
                                        vocab_size=vocab)
    fed = partition_noniid(X, y, Xt, yt, n_devices=cfg["n_devices"],
                           size_range=(6, 10), seed=cfg["seed"])
    return sp, pop, fed


def run_job(run_name, out_jsonl="results/model_zoo_runs.jsonl",
            dryrun=False, **overrides):
    cfg = update_config_fields(BASE_CONFIG, overrides)
    if dryrun:
        print(f"DRYRUN {run_name}: {cfg}")
        return None

    from repro.core.compression import CompressionConfig
    from repro.core.sweep import SweepRunner, build_scheduler

    t0 = time.time()
    sp, pop, fed = _world(cfg)
    comp_cfg = CompressionConfig(codec=cfg["codec"])
    runner = SweepRunner(sp, [(pop, fed)], lr=cfg["lr"],
                         alloc_steps=cfg["alloc_steps"], arch=cfg["arch"],
                         compression=comp_cfg)
    sched, cstats = build_scheduler(cfg["scheduler"], fed, sp, cfg["H"],
                                    seed=cfg["seed"], pop=pop,
                                    arch=cfg["arch"])
    res = runner.run([sched], cfg["rounds"], assign=cfg["assign"],
                     fused=True)
    rec = {
        "run_name": run_name, **cfg,
        "accs": [float(a) for a in res["acc"][0]],
        "final_acc": float(res["acc"][0, -1]),
        "T_total": float(res["T_i"][0].sum()),
        "E_total": float(res["E_i"][0].sum()),
        "model_bits": float(runner.model_bits),
        "uplink_bits_per_msg": float(res["uplink_bits_per_msg"]),
        "n_dispatches": int(res["n_dispatches"]),
        "clustering": {k: float(v) for k, v in cstats.items()},
        "wall_s": time.time() - t0,
    }
    os.makedirs(os.path.dirname(out_jsonl) or ".", exist_ok=True)
    with open(out_jsonl, "a") as fh:
        fh.write(json.dumps(rec) + "\n")
    print(f"{run_name}: acc={rec['final_acc']:.3f} "
          f"T={rec['T_total']:.0f}s E={rec['E_total']:.0f}J "
          f"uplink={rec['uplink_bits_per_msg']:.0f}b "
          f"({rec['wall_s']:.1f}s wall)")
    return rec


def matrix_jobs(smoke=False):
    """(run_name, overrides) pairs for the sweep matrix."""
    from repro.configs.registry import HFL_SMOKE_ARCHS

    if smoke:
        # one job per arch family, tiny shapes, codec + scheduler mixed
        # in so the CI lane exercises every axis of the matrix
        tiny = {"rounds": 2, "n_train": 240, "n_test": 64}
        jobs = [
            ("cnn_ikc_none", {"arch": "hfl-cnn", "scheduler": "ikc",
                              "lr": 0.01, **tiny}),
            ("dense_fedavg_int8", {"arch": "mistral-nemo-12b",
                                   "codec": "int8", **tiny}),
            ("ssm_fedavg_none", {"arch": "mamba2-2.7b", **tiny}),
            ("moe_fedavg_topk", {"arch": "qwen3-moe-235b-a22b",
                                 "codec": "topk", **tiny}),
        ]
        return jobs
    jobs = []
    for arch in HFL_SMOKE_ARCHS:
        short = arch.split("-")[0]
        for scheduler in ("fedavg", "ikc"):
            for codec in ("none", "int8"):
                name = f"{short}_{scheduler}_{codec}"
                over = {"arch": arch, "scheduler": scheduler,
                        "codec": codec}
                if arch == "hfl-cnn":
                    over["lr"] = 0.01
                jobs.append((name, over))
    return jobs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny job per arch family (CI lane)")
    ap.add_argument("--dryrun", action="store_true",
                    help="print the job matrix without running")
    ap.add_argument("--out", default="results/model_zoo_runs.jsonl")
    args = ap.parse_args()

    jobs = matrix_jobs(smoke=args.smoke)
    print(f"launching {len(jobs)} jobs "
          f"({'smoke' if args.smoke else 'full matrix'})")
    recs = [run_job(name, out_jsonl=args.out, dryrun=args.dryrun, **over)
            for name, over in jobs]
    if args.dryrun:
        return
    assert all(r is not None for r in recs)
    if args.smoke:
        # the CI gate: every family's job really trained and accounted
        assert all(0.0 <= r["final_acc"] <= 1.0 for r in recs)
        assert all(r["n_dispatches"] == 1 for r in recs)
        for r in recs:
            if r["codec"] != "none":
                assert r["uplink_bits_per_msg"] < r["model_bits"]
        print(f"smoke pass: {len(recs)} jobs, "
              f"families={[r['arch'] for r in recs]}")


if __name__ == "__main__":
    main()
