"""End-to-end driver: full HFL training run to target accuracy with the
paper's complete loop — IKC scheduling + D3QN assignment (trained inline,
Algorithm 5) + convex resource allocation + Algorithm-1 training —
compared against the FedAvg/geo baseline.

    PYTHONPATH=src python examples/train_hfl_e2e.py [--rounds 8] [--episodes 80]

This is the paper's experiment at reduced scale (CPU container); the
relative outcome (proposed framework reaches the target with lower E+λT)
is the reproduced claim.
"""
import argparse
import time


from repro.core.cost_model import SystemParams, sample_population
from repro.core.framework import FrameworkConfig, HFLFramework
from repro.data import make_dataset, partition_noniid
from repro.drl.train import D3QNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--episodes", type=int, default=80,
                    help="D3QN pre-training episodes (Algorithm 5)")
    ap.add_argument("--H", type=int, default=20)
    ap.add_argument("--engine", choices=("fused", "sequential"),
                    default="fused",
                    help="fused batched round engine (default) or the "
                         "per-edge sequential oracle")
    args = ap.parse_args()
    t0 = time.time()

    sp = SystemParams(n_devices=40, n_edges=5, d_range=(50, 90))
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=5000, n_test=800, seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=40, size_range=(50, 90),
                           seed=0)

    # --- Algorithm 5: train the D3QN assignment agent offline
    print(f"[{time.time()-t0:5.1f}s] training D3QN for {args.episodes} episodes")
    trainer = D3QNTrainer(sp, H=args.H, hidden=128, hfel_transfer=30,
                          hfel_exchange=60, alloc_steps=60,
                          eps_decay_episodes=args.episodes // 2, seed=0)
    trainer.train(max_episodes=args.episodes, log_every=25)

    # --- Algorithm 6 with the proposed components
    results = {}
    for name, sched, assign, drl in (
            ("proposed(IKC+D3QN)", "ikc", "drl", trainer.params),
            ("baseline(FedAvg+geo)", "fedavg", "geo", None)):
        cfg = FrameworkConfig(scheduler=sched, assigner=assign, H=args.H,
                              K=10, target_acc=0.70, max_iters=args.rounds,
                              seed=0, engine=args.engine)
        fw = HFLFramework(sp, pop, fed, cfg, drl_params=drl)
        print(f"[{time.time()-t0:5.1f}s] running {name}")
        results[name] = fw.run(verbose=True)

    print("\n=== comparison ===")
    for name, s in results.items():
        print(f"{name:24s} rounds={s['iters']:2d} acc={s['final_acc']:.3f} "
              f"T={s['T']:.0f}s E={s['E']:.0f}J obj={s['objective']:.0f}")
    prop = results["proposed(IKC+D3QN)"]
    base = results["baseline(FedAvg+geo)"]
    better = (prop["objective"] <= base["objective"] * 1.05
              or prop["final_acc"] >= base["final_acc"])
    print(f"paper claim (proposed framework reduces system cost): "
          f"{'REPRODUCED' if better else 'NOT reproduced at this scale'}")


if __name__ == "__main__":
    main()
