"""Device-assignment shootout on one sampled IoT population:
geographic vs HFEL-100 vs HFEL-300 (vs D3QN if a reward-trained agent is
available) — reproduces the Fig. 6 comparison interactively.

    PYTHONPATH=src python examples/assignment_demo.py [--H 20]
"""
import argparse
import time

import numpy as np

from repro.core.assignment import GeoAssigner, HFELAssigner
from repro.core.assignment.hfel import total_objective
from repro.core.cost_model import SystemParams
from repro.drl.train import make_training_population


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--H", type=int, default=20)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    sp = SystemParams(n_edges=5, lam=1.0)
    pop = make_training_population(sp, args.H, seed=args.seed)
    sched = np.arange(args.H)
    rng = np.random.default_rng(0)

    print(f"population: H={args.H} devices, M={sp.n_edges} edges, λ={sp.lam}")
    print(f"{'strategy':12s} {'obj E+λT':>12s} {'T_i (s)':>10s} "
          f"{'E_i (J)':>10s} {'latency':>10s}")
    for name, strat in (
            ("geo", GeoAssigner(sp)),
            ("hfel-100", HFELAssigner(sp, 100, 100, alloc_steps=120)),
            ("hfel-300", HFELAssigner(sp, 100, 300, alloc_steps=120))):
        t0 = time.perf_counter()
        a, _ = strat.assign(pop, sched, rng)
        lat = time.perf_counter() - t0
        obj, T_m, E_m = total_objective(sp, pop, sched, np.asarray(a),
                                        alloc_steps=120)
        counts = np.bincount(np.asarray(a), minlength=sp.n_edges)
        print(f"{name:12s} {obj:12.1f} {T_m.max():10.1f} {E_m.sum():10.1f} "
              f"{lat*1e3:8.0f}ms  edge loads={counts.tolist()}")


if __name__ == "__main__":
    main()
