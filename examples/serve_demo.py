"""Serve a small model with batched requests through the production
serve_step (KV/SSM cache decode) — smoke-scale variants of two assigned
architectures, one attention-based and one attention-free.

    PYTHONPATH=src python examples/serve_demo.py
"""
import subprocess
import sys


def main():
    for arch in ("mistral-nemo-12b", "mamba2-2.7b"):
        print(f"\n=== serving {arch} (smoke config) ===", flush=True)
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--smoke", "--batch", "4", "--prompt-len", "16",
             "--gen", "32"],
            check=True)


if __name__ == "__main__":
    main()
