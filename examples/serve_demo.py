"""Serving demos for both service CLIs.

    PYTHONPATH=src python examples/serve_demo.py [--smoke]

Full mode runs (1) the streaming async-HFL service
(``repro.launch.serve``) under the bursty traffic preset and (2) the
batched LM decode server (``repro.launch.serve_lm``) on smoke-scale
variants of two architectures, one attention-based and one
attention-free. ``--smoke`` is the bounded CI guard: just the streaming
HFL service on a tiny world (the examples-smoke job runs it on every
push — the point is that the public entry point still executes).
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="bounded CI mode: streaming HFL serve only")
    args = ap.parse_args()

    print("=== streaming async HFL service (smoke world) ===", flush=True)
    serve_cmd = [sys.executable, "-m", "repro.launch.serve", "--smoke"]
    if not args.smoke:
        serve_cmd += ["--traffic", "bursty", "--buffer-size", "2"]
    subprocess.run(serve_cmd, check=True)
    if args.smoke:
        return

    for arch in ("mistral-nemo-12b", "mamba2-2.7b"):
        print(f"\n=== serving {arch} (smoke config) ===", flush=True)
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve_lm", "--arch", arch,
             "--smoke", "--batch", "4", "--prompt-len", "16",
             "--gen", "32"],
            check=True)


if __name__ == "__main__":
    main()
