"""Model-zoo payloads: ModelSpec resolution, engine parity, accounting.

Coverage map:

* ``arch="hfl-cnn"`` (the default) — bitwise parity with the pre-spec
  engines: same init leaves, same ``cnn_apply`` function object (same
  static-jit cache key), identical one-round output when the old inline
  recipe is replayed against ``round_step`` directly.
* n_classes threading — the clustering auxiliary models take
  ``fed.n_classes`` (a 4-class world prices ``aux_bits`` from a 4-class
  head; an earlier revision silently defaulted to 10).
* every registry smoke arch — one ``HFLFramework`` round (the
  ``round_step`` engine) plus a fused single-dispatch sweep matching the
  per-round host loop, on the synthetic sequence task. The
  ``HFL_SMOKE_ARCHS`` families (dense/ssm/moe) run in tier-1; the rest
  of the registry is slow-marked for the weekly model-zoo-parity lane.
* ``evaluate_in_batches`` — padded-tail chunking is exact (one traced
  program per chunk shape, chunked == unchunked accuracy).
* ``message_bits()`` / codecs on embedding and MoE leaf shapes.
"""
import numpy as np
import pytest

_N, _M, _H = 8, 2, 4
_TIER1_ARCHS = ("mistral-nemo-12b", "mamba2-2.7b", "qwen3-moe-235b-a22b")


def _image_world(n_classes=10, seed=0):
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_dataset, partition_noniid

    sp = SystemParams(n_devices=_N, n_edges=_M)
    pop = sample_population(sp, seed=seed)
    if n_classes == 10:
        X, y, Xt, yt = make_dataset("fmnist_syn", n_train=240, n_test=64,
                                    seed=seed)
    else:   # random pixels are fine: these worlds only pin shapes/pricing
        rng = np.random.default_rng(seed)
        X = rng.random((240, 28, 28, 1)).astype(np.float32)
        y = rng.integers(0, n_classes, 240).astype(np.int32)
        Xt = rng.random((32, 28, 28, 1)).astype(np.float32)
        yt = rng.integers(0, n_classes, 32).astype(np.int32)
    fed = partition_noniid(X, y, Xt, yt, n_devices=_N, size_range=(6, 10),
                           n_classes=n_classes, seed=seed)
    return sp, pop, fed


def _seq_world(arch, seed=0):
    from repro.configs.registry import get_smoke_config
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_seq_dataset, partition_noniid

    vocab = min(257, get_smoke_config(arch).vocab_size)
    sp = SystemParams(n_devices=_N, n_edges=_M)
    pop = sample_population(sp, seed=seed)
    X, y, Xt, yt = make_seq_dataset(n_train=240, n_test=64, seed=seed,
                                    vocab_size=vocab)
    fed = partition_noniid(X, y, Xt, yt, n_devices=_N, size_range=(6, 10),
                           seed=seed)
    return sp, pop, fed


# ------------------------------------------------- hfl-cnn bitwise parity

def test_registry_spec_identity_and_default():
    from repro.configs.registry import (ARCH_IDS, HFL_SMOKE_ARCHS,
                                        get_hfl_spec)
    from repro.models import cnn

    spec = get_hfl_spec("hfl-cnn")
    assert spec is get_hfl_spec("hfl-cnn")          # cached: same object
    assert spec.apply_fn is cnn.cnn_apply           # same jit cache key
    assert spec.mini_apply_fn is cnn.mini_apply
    for arch in ARCH_IDS:
        s = get_hfl_spec(arch)
        assert s is get_hfl_spec(arch)
        assert s.apply_fn == get_hfl_spec(arch).apply_fn
    assert set(HFL_SMOKE_ARCHS) <= {"hfl-cnn", *ARCH_IDS}
    with pytest.raises(KeyError):
        get_hfl_spec("no-such-arch")


def test_hfl_cnn_bitwise_parity_with_pre_spec_engines():
    """The default arch replays the pre-spec construction bit for bit:
    identical init leaves in all three engines, identical one-round
    params when the old inline recipe drives ``round_step`` directly."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.assignment import GeoAssigner
    from repro.core.async_engine import AsyncConfig, AsyncHFLEngine
    from repro.core.framework import (FrameworkConfig, HFLFramework,
                                      round_step)
    from repro.core.hfl import evaluate_in_batches, pad_device_data
    from repro.core.scheduling import FedAvgScheduler
    from repro.core.sweep import SweepRunner
    from repro.models import cnn
    from repro.utils import tree_bytes

    sp, pop, fed = _image_world()
    cfg = FrameworkConfig(scheduler="fedavg", assigner="geo", H=_H,
                          alloc_steps=25, max_iters=1)
    fw = HFLFramework(sp, pop, fed, cfg)
    rec = fw.run_round(1)

    # --- init parity (framework / async / sweep), pre-spec recipes inline
    key = jax.random.PRNGKey(cfg.seed)
    k_model, _, _ = jax.random.split(key, 3)
    hw, ch = fed.X_test.shape[1:3], fed.X_test.shape[3]
    ref = cnn.cnn_init(k_model, hw, ch, fed.n_classes)

    fw2 = HFLFramework(sp, pop, fed, cfg)
    for a, b in zip(jax.tree.leaves(ref),
                    jax.tree.leaves(fw2.model_params)):
        assert (a == b).all()
    assert fw2.apply_fn is cnn.cnn_apply

    eng = AsyncHFLEngine(sp, pop, fed, AsyncConfig(H=_H, alloc_steps=25))
    for a, b in zip(jax.tree.leaves(ref),
                    jax.tree.leaves(eng.model_params)):
        assert (a == b).all()

    runner = SweepRunner(sp, [(pop, fed)] * 2, alloc_steps=25)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[cnn.cnn_init(k, hw, ch, fed.n_classes) for k in keys])
    for a, b in zip(jax.tree.leaves(stacked),
                    jax.tree.leaves(runner.params0)):
        assert (a == b).all()
    assert runner.apply_fn is cnn.cnn_apply

    # --- one-round parity: the old engine's exact call, replayed
    sp_r = dataclasses.replace(sp, model_bits=float(tree_bytes(ref) * 8))
    rng = np.random.default_rng(cfg.seed)
    sched = np.asarray(FedAvgScheduler(fed.n_devices, _H).schedule(rng))
    assign, _ = GeoAssigner(sp_r).assign(pop, sched, rng)
    X, y, mask = pad_device_data(fed)
    new_params, (T_i, E_i, _, _, _, _) = round_step(
        cnn.cnn_apply, sp_r, ref, pop.u[sched], pop.D[sched], pop.p[sched],
        pop.g[sched], pop.g_cloud, pop.B_m, X[sched], y[sched], mask[sched],
        pop.D[sched], jnp.asarray(np.asarray(assign)), cfg.lr,
        M=pop.n_edges, L=sp.L, Q=sp.Q, alloc_steps=cfg.alloc_steps)
    for a, b in zip(jax.tree.leaves(new_params),
                    jax.tree.leaves(fw.model_params)):
        assert (a == b).all()
    assert rec["T_i"] == float(T_i) and rec["E_i"] == float(E_i)
    assert rec["acc"] == evaluate_in_batches(cnn.cnn_apply, new_params,
                                             fed.X_test, fed.y_test)


# ----------------------------------------------- n_classes bug regression

def test_clustering_aux_models_take_fed_n_classes():
    """4-class world: clustering heads and aux_bits pricing follow
    ``fed.n_classes`` (the pre-fix path silently built 10-class heads)."""
    import jax

    from repro.configs.registry import get_hfl_spec
    from repro.core.sweep import build_scheduler
    from repro.core.scheduling import IKCScheduler, VKCScheduler
    from repro.models import cnn
    from repro.utils import tree_bytes

    sp, pop, fed4 = _image_world(n_classes=4)
    spec = get_hfl_spec("hfl-cnn")
    key = jax.random.PRNGKey(0)

    assert spec.init_fn(key, fed4)["fc2"].shape == (226, 4)
    assert spec.mini_init_fn(key, fed4)["fc"].shape[1] == 4

    sched_i, stats_i = build_scheduler("ikc", fed4, sp, _H, pop=pop)
    assert isinstance(sched_i, IKCScheduler)
    assert stats_i["aux_bits"] == tree_bytes(cnn.mini_init(key, 4)) * 8
    assert stats_i["aux_bits"] != tree_bytes(cnn.mini_init(key, 10)) * 8

    sched_v, stats_v = build_scheduler("vkc", fed4, sp, _H, pop=pop)
    assert isinstance(sched_v, VKCScheduler)
    full4 = cnn.cnn_init(key, (28, 28), 1, 4)
    full10 = cnn.cnn_init(key, (28, 28), 1, 10)
    assert stats_v["aux_bits"] == tree_bytes(full4) * 8
    assert stats_v["aux_bits"] != tree_bytes(full10) * 8


# --------------------------------------------- per-arch engine coverage

def _zoo_round_and_fused_parity(arch):
    """One framework round (the ``round_step`` engine) + fused-vs-host
    sweep parity on the synthetic sequence task."""
    from repro.core.framework import FrameworkConfig, HFLFramework
    from repro.core.sweep import SweepRunner, build_scheduler

    sp, pop, fed = _seq_world(arch)
    cfg = FrameworkConfig(arch=arch, scheduler="fedavg", assigner="geo",
                          H=_H, lr=0.3, alloc_steps=25, max_iters=1)
    fw = HFLFramework(sp, pop, fed, cfg)
    rec = fw.run_round(1)
    assert np.isfinite(rec["T_i"]) and np.isfinite(rec["E_i"])
    assert 0.0 <= rec["acc"] <= 1.0

    def run(fused):
        runner = SweepRunner(sp, [(pop, fed)], lr=0.3, alloc_steps=25,
                             arch=arch)
        scheds = [build_scheduler("fedavg", fed, sp, _H, seed=0)]
        return runner.run(scheds, 2, assign="geo", fused=fused)

    host, fused = run(False), run(True)
    assert fused["n_dispatches"] == 1
    for k in ("T_i", "E_i", "obj"):
        np.testing.assert_allclose(host[k], fused[k], rtol=1e-4, atol=1e-6,
                                   err_msg=k)
    np.testing.assert_allclose(host["acc"], fused["acc"], atol=0.09)
    # the task is learnable: two rounds beat the 10-class chance rate
    assert host["acc"][0, -1] > 0.2


@pytest.mark.parametrize("arch", _TIER1_ARCHS)
def test_zoo_arch_round_and_fused_parity(arch):
    _zoo_round_and_fused_parity(arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", [
    a for a in ("jamba-1.5-large-398b", "internvl2-26b", "chatglm3-6b",
                "musicgen-medium", "llama4-scout-17b-a16e", "llama3-405b",
                "mistral-large-123b")])
def test_zoo_arch_round_and_fused_parity_weekly(arch):
    _zoo_round_and_fused_parity(arch)


def test_async_engine_seq_arch():
    """The event-driven engine trains a non-CNN payload."""
    from repro.core.async_engine import AsyncConfig, AsyncHFLEngine

    sp, pop, fed = _seq_world("mamba2-2.7b")
    eng = AsyncHFLEngine(sp, pop, fed,
                         AsyncConfig(arch="mamba2-2.7b", H=_H, lr=0.3,
                                     alloc_steps=25))
    r1, r2 = eng.step_round(), eng.step_round()
    assert 0.0 <= r1["acc"] <= 1.0 and r2["acc"] > 0.15


def test_seq_ikc_clustering_recovers_majority_classes():
    """IKC's sequence mini model clusters devices by majority class."""
    from repro.core.sweep import build_scheduler
    from repro.core.scheduling import IKCScheduler

    sp, pop, fed = _seq_world("mamba2-2.7b")
    sched, stats = build_scheduler("ikc", fed, sp, _H, pop=pop,
                                   arch="mamba2-2.7b")
    assert isinstance(sched, IKCScheduler)
    assert stats["ari"] > 0.3
    assert 0 < stats["aux_bits"] < 1e6


# ------------------------------------------------- evaluate_in_batches

def test_evaluate_in_batches_padded_tail():
    """Chunked == unchunked accuracy, exactly; the ragged tail reuses the
    full-chunk program instead of tracing a second one."""
    import jax

    from repro.core.hfl import evaluate_accuracy, evaluate_in_batches
    from repro.models import cnn

    rng = np.random.default_rng(0)
    X = rng.random((130, 28, 28, 1)).astype(np.float32)
    y = rng.integers(0, 10, 130).astype(np.int32)
    params = cnn.cnn_init(jax.random.PRNGKey(0), (28, 28), 1, 10)

    shapes = []

    def apply(p, x):
        shapes.append(x.shape)      # appended once per trace
        return cnn.cnn_apply(p, x)

    acc_chunked = evaluate_in_batches(apply, params, X, y, batch=64)
    assert shapes == [(64, 28, 28, 1)]      # one trace, tail included
    acc_full = evaluate_in_batches(cnn.cnn_apply, params, X, y, batch=130)
    assert acc_chunked == acc_full          # integer counting: exact
    ref = float(evaluate_accuracy(cnn.cnn_apply, params, X, y))
    np.testing.assert_allclose(acc_chunked, ref, atol=1e-6)
    # batch > n must clamp, not pad a mostly-dead chunk
    assert evaluate_in_batches(cnn.cnn_apply, params, X[:5], y[:5],
                               batch=512) == \
        evaluate_in_batches(cnn.cnn_apply, params, X[:5], y[:5], batch=5)


# ------------------------------------- codec accounting on zoo payloads

def test_message_bits_on_embedding_and_moe_leaves():
    """message_bits() prices embedding/MoE leaf shapes exactly: raw =
    32 bits/elem, bf16 = 16 bits/elem, int8 = 8 bits/elem + one f32
    scale per leaf, topk = k * (32 + ceil(log2 n)) per leaf."""
    import math

    import jax

    from repro.configs.registry import get_hfl_spec
    from repro.core import compression as comp

    spec = get_hfl_spec("qwen3-moe-235b-a22b")
    sp, pop, fed = _seq_world("qwen3-moe-235b-a22b")
    params = spec.init_fn(jax.random.PRNGKey(0), fed)
    leaves = jax.tree.leaves(params)
    sizes = [leaf.size for leaf in leaves]
    n_elem = sum(sizes)
    # the payload really has embedding + stacked-expert leaves
    assert any(leaf.ndim >= 4 for leaf in leaves)           # MoE stacks
    assert params["embed"].shape[0] >= 256                  # vocab rows

    raw = comp.message_bits(comp.CompressionConfig(), params)
    assert raw == 32 * n_elem
    bf16 = comp.message_bits(comp.CompressionConfig(codec="bf16_delta"),
                             params)
    assert bf16 == 16 * n_elem and raw / bf16 == 2.0
    int8 = comp.message_bits(comp.CompressionConfig(codec="int8"), params)
    assert int8 == 8 * n_elem + 32 * len(leaves)
    frac = 0.05
    topk = comp.message_bits(
        comp.CompressionConfig(codec="topk", topk_frac=frac), params)
    expect = sum(min(n, max(1, int(round(frac * n)))) *
                 (32 + max(1, math.ceil(math.log2(n)))) for n in sizes)
    assert topk == expect


def test_int8_roundtrip_on_embedding_leaf():
    """The int8 codec's decode error is bounded by one quantisation step
    per row on an embedding-shaped leaf."""
    import jax

    from repro.core import compression as comp

    cfg = comp.CompressionConfig(codec="int8")
    key = jax.random.PRNGKey(0)
    emb = jax.random.normal(key, (257, 64)) * 0.02
    rows = np.asarray(emb).reshape(4, -1)       # 4 messages
    q, scale = comp.encode_rows(cfg, key, rows)
    dec = np.asarray(comp.decode_rows(cfg, q, scale))
    err = np.abs(dec - rows).max(axis=1)
    assert (err <= np.asarray(scale) * (1 + 1e-6)).all()
