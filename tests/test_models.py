"""Model substrate: forward/grad per family, decode==forward, SSD oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.models import transformer as T
from repro.models.mamba2 import ssd_chunked, ssd_reference

KEY = jax.random.PRNGKey(0)


def _cfg(name, **kw):
    base = dict(name=name, family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense": _cfg("dense"),
    "swa": _cfg("swa", sliding_window=5),
    "moe": _cfg("moe", family="moe", d_ff=96,
                moe=MoEConfig(4, 2, capacity_factor=8.0)),
    "ssm": _cfg("ssm", family="ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                ssm=SSMConfig(d_state=16, head_dim=16, chunk=8)),
    "hybrid": _cfg("hybrid", family="hybrid", n_layers=4, d_ff=96,
                   ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
                   hybrid_period=2, hybrid_attn_pos=0,
                   moe=MoEConfig(4, 2, every=2, capacity_factor=8.0)),
    "vlm": _cfg("vlm", family="vlm", n_prefix_embeds=8),
    "audio": _cfg("audio", family="audio", n_kv_heads=4, vocab_size=33,
                  n_codebooks=4),
}


def _batch(cfg, batch=2, seq=16):
    shape = (batch, seq) if cfg.n_codebooks == 1 else (batch, seq, cfg.n_codebooks)
    tok = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    b = {"tokens": tok, "labels": tok}
    if cfg.n_prefix_embeds:
        b["prefix_embeds"] = jax.random.normal(
            KEY, (batch, cfg.n_prefix_embeds, cfg.d_model))
    return b


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_forward_and_grad(fam):
    cfg = FAMILIES[fam]
    params = T.init(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(params, batch, cfg)
    exp_v = cfg.vocab_size
    if cfg.n_codebooks > 1:
        assert logits.shape == (2, 16, cfg.n_codebooks, exp_v)
    else:
        assert logits.shape == (2, 16, exp_v)
    assert not bool(jnp.isnan(logits).any())
    (loss, metrics), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert jnp.isfinite(loss)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0


@pytest.mark.parametrize("fam", ["dense", "swa", "ssm", "hybrid", "audio"])
def test_decode_matches_forward(fam):
    cfg = FAMILIES[fam]
    seq = 8
    params = T.init(KEY, cfg)
    shape = (2, seq) if cfg.n_codebooks == 1 else (2, seq, cfg.n_codebooks)
    tok = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    full, _ = T.forward(params, {"tokens": tok}, cfg)
    cache = T.init_cache(cfg, 2, max_len=seq)
    outs = []
    for t in range(seq):
        lg, cache = T.decode(params, tok[:, t:t + 1], cache, jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(dec, full, atol=5e-4), float(jnp.max(jnp.abs(dec - full)))


def test_swa_cache_is_rolling():
    cfg = FAMILIES["swa"]
    cache = T.init_cache(cfg, 2, max_len=100)
    # window 5 -> 5 slots regardless of max_len
    assert cache[0]["k"].shape[2] == 5


def test_ssd_chunked_matches_reference():
    k = jax.random.PRNGKey(1)
    B, S, H, P, N, G = 2, 64, 4, 8, 16, 1
    ks = jax.random.split(k, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, G, N))
    C_ = jax.random.normal(ks[0], (B, S, G, N))
    for chunk in (8, 16, 64):
        y1 = ssd_chunked(x, dt, A, B_, C_, chunk)
        y2 = ssd_reference(x, dt, A, B_, C_)
        assert jnp.allclose(y1, y2, atol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor=tiny, most tokens must be dropped (output ~0);
    with huge factor, outputs differ."""
    from repro.models import moe as moe_lib
    cfg_tight = _cfg("m", family="moe", d_ff=32,
                     moe=MoEConfig(4, 1, capacity_factor=0.01))
    cfg_loose = _cfg("m", family="moe", d_ff=32,
                     moe=MoEConfig(4, 1, capacity_factor=8.0))
    x = jax.random.normal(KEY, (2, 32, 64))
    p = moe_lib.moe_init(KEY, cfg_tight)
    out_t, _ = moe_lib.moe_apply(p, x, cfg_tight)
    out_l, _ = moe_lib.moe_apply(p, x, cfg_loose)
    # tight capacity zeroes most token outputs
    frac_zero_t = float(jnp.mean(jnp.all(out_t == 0, axis=-1)))
    frac_zero_l = float(jnp.mean(jnp.all(out_l == 0, axis=-1)))
    assert frac_zero_t > 0.5
    assert frac_zero_l == 0.0


def test_moe_aux_loss_balanced_router_is_one():
    """Perfectly uniform router -> aux loss == 1 (Switch normalisation)."""
    from repro.models import moe as moe_lib
    cfg = _cfg("m", family="moe", d_ff=32, moe=MoEConfig(4, 1))
    p = moe_lib.moe_init(KEY, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(KEY, (2, 64, 64))
    _, aux = moe_lib.moe_apply(p, x, cfg)
    # me uniform = 1/E; ce depends on top-1 tie-break -> E * sum(me*ce) == 1
    assert abs(float(aux) - 1.0) < 1e-5
