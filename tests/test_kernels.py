"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.hier_agg.ops import (aggregate_pytrees, masked_aggregate,
                                        masked_decode_aggregate,
                                        weighted_aggregate)
from repro.kernels.hier_agg.ref import (masked_aggregate_ref,
                                        masked_decode_aggregate_ref,
                                        weighted_aggregate_ref)
from repro.kernels.kmeans_dist.ops import pairwise_sq_dists
from repro.kernels.kmeans_dist.ref import pairwise_sq_dists_ref

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------- kmeans_dist

@pytest.mark.parametrize("N,P,K", [
    (100, 2540, 10),    # IKC mini-model weights, K=10 clusters
    (37, 130, 3),       # unaligned everything
    (256, 512, 128),    # exact tiles
    (5, 7, 2),          # tiny
    (300, 1024, 16),
    (64, 600, 200),     # K > BK: multiple centroid-panel grid blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_dist_sweep(N, P, K, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (N, P), dtype)
    c = jax.random.normal(k2, (K, P), dtype)
    out = pairwise_sq_dists(x, c, interpret=True)
    ref = pairwise_sq_dists_ref(x, c)
    tol = 2e-3 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * float(jnp.max(ref)))


def test_kmeans_dist_is_actually_squared_distance():
    x = jnp.array([[0.0, 0.0], [3.0, 4.0]])
    c = jnp.array([[0.0, 0.0]])
    out = pairwise_sq_dists(x, c, interpret=True)
    np.testing.assert_allclose(np.asarray(out), [[0.0], [25.0]], atol=1e-5)


def test_default_interpret_gates_on_cpu_only(monkeypatch):
    """Regression (ISSUE 6): interpret-mode emulation is a CPU fallback;
    pre-fix the gate was ``!= "tpu"``, forcing interpret on real GPUs."""
    from repro.kernels.flash_attention import ops as fa_ops
    from repro.kernels.hier_agg import ops as ha_ops
    from repro.kernels.kmeans_dist import ops as kd_ops

    for backend, expect in [("cpu", True), ("gpu", False), ("tpu", False)]:
        monkeypatch.setattr(jax, "default_backend", lambda b=backend: b)
        for mod in (kd_ops, ha_ops, fa_ops):
            assert mod._default_interpret() is expect, (
                mod.__name__, backend)


# ------------------------------------------------------------ hier_agg

@pytest.mark.parametrize("M,H,P", [(5, 50, 114383), (1, 3, 17), (8, 128, 4096),
                                   (5, 100, 2540)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hier_agg_sweep(M, H, P, dtype):
    k1, k2 = jax.random.split(KEY)
    w = jax.random.uniform(k1, (M, H), jnp.float32)
    w = w / w.sum(axis=1, keepdims=True)
    d = jax.random.normal(k2, (H, P), dtype)
    out = weighted_aggregate(w, d, interpret=True)
    ref = weighted_aggregate_ref(w, d)
    tol = 1e-4 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def _one_hot_mask(rng, M, H, empty=()):
    """(M, H) membership rows from a random assignment; ``empty`` edges
    get their devices reassigned so their rows are all-zero."""
    assign = rng.integers(0, M, H)
    for m in empty:
        assign[assign == m] = (m + 1) % M
    return (assign[None, :] == np.arange(M)[:, None]).astype(np.float32)


@pytest.mark.parametrize("M,H,P", [
    (5, 50, 114383),    # paper shape, unaligned everything
    (3, 13, 257),       # non-multiple-of-8 M and H
    (1, 3, 17),         # single edge (the cloud-aggregation layout)
    (8, 128, 4096),     # exact tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_agg_sweep(M, H, P, dtype):
    """Fused masked-weight kernel == einsum oracle that materialises the
    normalised (M, H) weight panel."""
    rng = np.random.default_rng(0)
    mask = _one_hot_mask(rng, M, H)
    sizes = jnp.asarray(rng.uniform(10, 100, H).astype(np.float32))
    d = jax.random.normal(KEY, (H, P), dtype)
    out = masked_aggregate(jnp.asarray(mask), sizes, d, interpret=True)
    ref = masked_aggregate_ref(jnp.asarray(mask), sizes, d)
    tol = 1e-4 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_masked_agg_empty_edges():
    """All-zero one-hot rows (edges with no scheduled devices) produce
    all-zero output rows — the engine's has_dev fixup then keeps the old
    edge model."""
    rng = np.random.default_rng(1)
    M, H, P = 6, 30, 1037
    mask = _one_hot_mask(rng, M, H, empty=(2, 5))
    sizes = jnp.asarray(rng.uniform(10, 100, H).astype(np.float32))
    d = jax.random.normal(KEY, (H, P), jnp.float32)
    out = np.asarray(masked_aggregate(jnp.asarray(mask), sizes, d,
                                      interpret=True))
    ref = np.asarray(masked_aggregate_ref(jnp.asarray(mask), sizes, d))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    assert np.all(out[2] == 0.0) and np.all(out[5] == 0.0)
    assert np.any(out[0] != 0.0)


def test_masked_agg_vmapped_lanes():
    """vmap over a lane axis hits the (S, P/BP) batched kernel via the
    custom_vmap rule and matches per-lane oracles — including unbatched
    operands (the constant cloud mask case), which the rule broadcasts."""
    rng = np.random.default_rng(2)
    S, M, H, P = 3, 5, 26, 700
    masks = np.stack([_one_hot_mask(rng, M, H) for _ in range(S)])
    sizes = rng.uniform(10, 100, (S, H)).astype(np.float32)
    d = np.asarray(jax.random.normal(KEY, (S, H, P), jnp.float32))
    out = jax.vmap(masked_aggregate)(jnp.asarray(masks), jnp.asarray(sizes),
                                     jnp.asarray(d))
    ref = np.stack([np.asarray(masked_aggregate_ref(
        jnp.asarray(masks[s]), jnp.asarray(sizes[s]), jnp.asarray(d[s])))
        for s in range(S)])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)

    # unbatched mask/sizes closed over, only deltas vmapped
    m0, s0 = jnp.asarray(masks[0]), jnp.asarray(sizes[0])
    out2 = jax.vmap(lambda dd: masked_aggregate(m0, s0, dd))(jnp.asarray(d))
    ref2 = np.stack([np.asarray(masked_aggregate_ref(m0, s0,
                                                     jnp.asarray(d[s])))
                     for s in range(S)])
    np.testing.assert_allclose(np.asarray(out2), ref2, rtol=1e-4, atol=1e-4)


def _wire_q(rng, H, P, dtype):
    """Wire-format update rows as each codec emits them: int8 quantized
    levels, bf16 cast deltas, or dense-masked f32 (topk)."""
    if dtype == jnp.int8:
        return jnp.asarray(rng.integers(-127, 128, (H, P)), jnp.int8)
    x = jax.random.normal(KEY, (H, P), jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("M,H,P", [
    (5, 50, 114383),    # paper shape, unaligned everything
    (3, 13, 257),       # non-multiple-of-8 M and H
    (1, 3, 17),         # single edge (the cloud-hop layout)
    (8, 128, 4096),     # exact tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_masked_decode_agg_sweep(M, H, P, dtype):
    """Fused decode-aggregate == dense-decode-then-masked-aggregate
    oracle, for every wire dtype the codecs emit (the int8 operand
    forces the 32-sublane tile padding path)."""
    rng = np.random.default_rng(0)
    mask = _one_hot_mask(rng, M, H)
    sizes = jnp.asarray(rng.uniform(10, 100, H).astype(np.float32))
    scales = jnp.asarray(rng.uniform(1e-3, 2e-2, H).astype(np.float32))
    q = _wire_q(rng, H, P, dtype)
    out = masked_decode_aggregate(jnp.asarray(mask), sizes, scales, q,
                                  interpret=True)
    ref = masked_decode_aggregate_ref(jnp.asarray(mask), sizes, scales, q)
    tol = 1e-4 if dtype != jnp.bfloat16 else 0.05
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_masked_decode_agg_unit_scales_match_masked_agg():
    """With all-ones scales and an f32 operand the decode variant is the
    plain masked aggregation."""
    rng = np.random.default_rng(3)
    M, H, P = 4, 21, 911
    mask = jnp.asarray(_one_hot_mask(rng, M, H))
    sizes = jnp.asarray(rng.uniform(10, 100, H).astype(np.float32))
    d = jax.random.normal(KEY, (H, P), jnp.float32)
    out = masked_decode_aggregate(mask, sizes, jnp.ones((H,)), d,
                                  interpret=True)
    ref = masked_aggregate(mask, sizes, d, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8])
def test_masked_decode_agg_vmapped_lanes(dtype):
    """vmap over lanes hits the (S, P/BP) batched decode kernel via the
    custom_vmap rule — including the cloud-hop case where the all-ones
    mask is closed over unbatched."""
    rng = np.random.default_rng(4)
    S, M, H, P = 3, 5, 26, 700
    masks = np.stack([_one_hot_mask(rng, M, H) for _ in range(S)])
    sizes = rng.uniform(10, 100, (S, H)).astype(np.float32)
    scales = rng.uniform(1e-3, 2e-2, (S, H)).astype(np.float32)
    q = jnp.stack([_wire_q(rng, H, P, dtype) for _ in range(S)])
    out = jax.vmap(masked_decode_aggregate)(
        jnp.asarray(masks), jnp.asarray(sizes), jnp.asarray(scales), q)
    ref = np.stack([np.asarray(masked_decode_aggregate_ref(
        jnp.asarray(masks[s]), jnp.asarray(sizes[s]),
        jnp.asarray(scales[s]), q[s])) for s in range(S)])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)

    m0 = jnp.ones((1, M), jnp.float32)      # cloud hop: unbatched mask
    s0 = jnp.asarray(sizes[:, :M])
    sc0 = jnp.asarray(scales[:, :M])
    q0 = q[:, :M]
    out2 = jax.vmap(lambda ss, sc, qq: masked_decode_aggregate(
        m0, ss, sc, qq))(s0, sc0, q0)
    ref2 = np.stack([np.asarray(masked_decode_aggregate_ref(
        m0, s0[s], sc0[s], q0[s])) for s in range(S)])
    np.testing.assert_allclose(np.asarray(out2), ref2, rtol=1e-4, atol=1e-4)


def test_weighted_agg_vmapped_lanes():
    """The plain-panel kernel is batch-aware too (one launch per round
    for pre-normalised weight panels under vmap)."""
    S, M, H, P = 2, 4, 19, 513
    k1, k2 = jax.random.split(KEY)
    w = jax.random.uniform(k1, (S, M, H), jnp.float32)
    w = w / w.sum(axis=-1, keepdims=True)
    d = jax.random.normal(k2, (S, H, P), jnp.float32)
    out = jax.jit(jax.vmap(weighted_aggregate))(w, d)
    ref = np.stack([np.asarray(weighted_aggregate_ref(w[s], d[s]))
                    for s in range(S)])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_hier_agg_pytrees_matches_manual():
    params = {"a": jax.random.normal(KEY, (4, 3, 5)),
              "b": jax.random.normal(KEY, (4, 7))}
    w = jnp.array([[0.25, 0.25, 0.25, 0.25], [1.0, 0.0, 0.0, 0.0]])
    out = aggregate_pytrees(w, params, interpret=True)
    np.testing.assert_allclose(np.asarray(out["a"][0]),
                               np.asarray(params["a"].mean(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["b"][1]),
                               np.asarray(params["b"][0]), atol=1e-5)


# ------------------------------------------------------ flash attention

@pytest.mark.parametrize("B,S,Hq,Hkv,d,window", [
    (1, 128, 4, 2, 64, 0),
    (2, 256, 4, 4, 32, 0),
    (1, 256, 8, 2, 64, 96),    # GQA + sliding window
    (1, 200, 4, 2, 64, 0),     # unaligned seq
    (1, 128, 2, 1, 80, 50),    # unaligned head dim (pad to 128)
    (2, 384, 6, 3, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, Hq, Hkv, d, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, d), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, d), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, d), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 0.03
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_matches_model_attention():
    """The kernel must agree with the model's XLA attention path end to end."""
    from repro.configs.base import ModelConfig
    from repro.models.attention import attn_forward, attn_init
    cfg = ModelConfig("t", "dense", 2, 64, 4, 2, 128, 97, dtype="float32")
    params = attn_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, 64))
    out_xla = attn_forward(params, x, cfg, impl="xla")
    out_pl = attn_forward(params, x, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_xla),
                               atol=2e-4, rtol=1e-3)
