"""Checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_pytree, save_pytree


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.zeros(2), jnp.full((1,), 7)]},
            "step": jnp.int32(17)}
    d = str(tmp_path / "ck")
    save_pytree(tree, d, step=3)
    save_pytree(jax.tree.map(lambda x: x + 1 if x.dtype != jnp.bfloat16 else x,
                             tree), d, step=7)
    assert latest_step(d) == 7
    out3 = restore_pytree(tree, d, step=3)
    np.testing.assert_array_equal(np.asarray(out3["a"]),
                                  np.asarray(tree["a"]))
    out7 = restore_pytree(tree, d)
    np.testing.assert_array_equal(np.asarray(out7["a"]),
                                  np.asarray(tree["a"]) + 1)
    assert int(out7["step"]) == 18


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
