"""Per assigned architecture: REDUCED same-family variant runs one forward
and one train step on CPU; output shapes + no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _batch(cfg, batch=2, seq=16):
    shape = (batch, seq) if cfg.n_codebooks == 1 else (batch, seq, cfg.n_codebooks)
    tok = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    b = {"tokens": tok, "labels": tok}
    if cfg.n_prefix_embeds:
        b["prefix_embeds"] = jax.random.normal(
            KEY, (batch, cfg.n_prefix_embeds, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_variant(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.moe.num_experts <= 4
    params = T.init(KEY, cfg)
    batch = _batch(cfg)
    logits, _ = T.forward(params, batch, cfg)
    B, S = batch["tokens"].shape[:2]
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    # one SGD train step must reduce nothing NaN and change params
    (loss, _), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert jnp.isfinite(loss)
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    (loss2, _) = T.loss_fn(new_params, batch, cfg)[0], None
    assert jnp.isfinite(loss2[0] if isinstance(loss2, tuple) else loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_metadata(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    assert cfg.citation, "configs must cite their source"
    expected = {
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=24576, vocab_size=65536),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560),
        "chatglm3-6b": dict(n_layers=28, d_model=4096, n_heads=32,
                            n_kv_heads=2, d_ff=13696, vocab_size=65024),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab_size=131072),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab_size=2048),
        "llama4-scout-17b-a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv_heads=8, d_ff=8192),
        "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, d_ff=1536, vocab_size=151936),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab_size=128256),
        "mistral-large-123b": dict(n_layers=88, d_model=12288, n_heads=96,
                                   n_kv_heads=8, d_ff=28672, vocab_size=32768),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_moe_expert_counts():
    assert get_config("jamba-1.5-large-398b").moe.num_experts == 16
    assert get_config("jamba-1.5-large-398b").moe.top_k == 2
    assert get_config("llama4-scout-17b-a16e").moe.num_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe.top_k == 1
    assert get_config("qwen3-moe-235b-a22b").moe.num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8


def test_param_counts_in_expected_range():
    """Analytic parameter counts should land near the advertised sizes."""
    def pc(a):
        return get_config(a).param_count()
    assert 380e9 < pc("jamba-1.5-large-398b") < 440e9
    assert 18e9 < pc("internvl2-26b") < 26e9      # language backbone only
    assert 2.4e9 < pc("mamba2-2.7b") < 3.1e9
    assert 5.5e9 < pc("chatglm3-6b") < 7.5e9
    assert 11e9 < pc("mistral-nemo-12b") < 14e9
    assert 1.2e9 < pc("musicgen-medium") < 2.2e9
    assert 380e9 < pc("llama3-405b") < 430e9
    assert 115e9 < pc("mistral-large-123b") < 130e9
    q = get_config("qwen3-moe-235b-a22b")
    assert 200e9 < q.param_count() < 260e9
    assert 18e9 < q.active_param_count() < 28e9
    s = get_config("llama4-scout-17b-a16e")
    assert 95e9 < s.param_count() < 120e9         # 16 full experts
    # top-1 of 16 experts, no shared expert modelled -> ~11B active
    assert 9e9 < s.active_param_count() < 20e9
