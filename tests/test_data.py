"""Data substrate: synthetic datasets, non-IID partition, pipelines."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data import batch_iterator, make_dataset, partition_noniid
from repro.data.pipeline import token_batch_iterator


def test_dataset_shapes_and_ranges():
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=500, n_test=100, seed=0)
    assert X.shape == (500, 28, 28, 1) and Xt.shape == (100, 28, 28, 1)
    assert X.min() >= 0 and X.max() <= 1
    assert set(np.unique(y)) <= set(range(10))
    Xc, yc, _, _ = make_dataset("cifar_syn", n_train=200, n_test=50, seed=0)
    assert Xc.shape == (200, 32, 32, 3)


def test_classes_are_separable_by_nearest_prototype():
    """The synthetic data must be learnable: nearest-class-mean accuracy
    well above chance."""
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=2000, n_test=400, seed=1)
    means = np.stack([X[y == c].mean(0) for c in range(10)])
    d = ((Xt[:, None] - means[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == yt).mean()
    assert acc > 0.5, acc


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_partition_properties(seed):
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=800, n_test=50,
                                seed=seed % 3)
    fed = partition_noniid(X, y, Xt, yt, n_devices=20, size_range=(30, 60),
                           majority_frac=0.8, seed=seed)
    assert fed.n_devices == 20
    assert np.all(fed.sizes >= 30) and np.all(fed.sizes <= 60)
    # majority class dominates each device
    for n in range(20):
        frac = (fed.y[n] == fed.majority_class[n]).mean()
        assert frac >= 0.5, (n, frac)
    # all classes appear as majority roughly evenly
    counts = np.bincount(fed.majority_class, minlength=10)
    assert counts.max() - counts.min() <= 1


def test_batch_iterator_covers_epoch():
    X = np.arange(10)[:, None]
    y = np.arange(10)
    it = batch_iterator(X, y, 3, seed=0)
    seen = []
    for _ in range(4):
        xb, yb = next(it)
        seen.extend(yb.tolist())
    assert sorted(seen[:10]) == list(range(10))


def test_token_iterator_shapes():
    it = token_batch_iterator(vocab=50, batch=4, seq=16, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()
    assert b["tokens"].max() < 50
