"""Unit tests for the ``benchmarks.run --check`` regression guard.

Pure-python (no JAX): pins the ``_perf_fields`` suffix contract and the
per-field noise floor of ``check_regressions`` — in particular the
ISSUE-8 bugfix where a sub-floor baseline used to be *skipped* (so a
4ms -> 400ms regression passed silently) and is now gated against
``max(baseline, floor_ms) * factor``. Contract: ``benchmarks/README.md``.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.run import _perf_fields, check_regressions  # noqa: E402


def _write(dirpath: Path, name: str, obj: dict) -> None:
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / name).write_text(json.dumps(obj))


def _dirs(tmp_path: Path, base: dict, fresh: dict,
          name: str = "BENCH_x_smoke.json"):
    _write(tmp_path / "baselines", name, base)
    _write(tmp_path / "results", name, fresh)
    return str(tmp_path / "results"), str(tmp_path / "baselines")


def test_perf_fields_suffixes_and_nesting():
    fields = _perf_fields({
        "round_ms": 3.0,
        "search_s": 0.25,
        "train_eps_per_s": 40.0,
        "final_acc": 0.9,            # ignored: no perf suffix
        "rounds": 4,                 # ignored
        "cases": [{"wall_per_round_ms": 7.0, "n_stale": 2}],
        "nested": {"probe_ms": 1.0},
    })
    assert fields["round_ms"] == (3.0, "time")
    assert fields["search_s"] == (250.0, "time")      # normalised to ms
    assert fields["train_eps_per_s"] == (40.0, "rate")
    assert fields["cases.0.wall_per_round_ms"] == (7.0, "time")
    assert fields["nested.probe_ms"] == (1.0, "time")
    assert "final_acc" not in fields
    assert "cases.0.n_stale" not in fields


def test_check_passes_within_factor(tmp_path):
    res, base = _dirs(tmp_path, {"round_ms": 10.0}, {"round_ms": 15.0})
    assert check_regressions(res, base, factor=2.0) == []


def test_check_fails_on_slowdown(tmp_path):
    res, base = _dirs(tmp_path, {"round_ms": 10.0}, {"round_ms": 25.0})
    fails = check_regressions(res, base, factor=2.0)
    assert len(fails) == 1 and "round_ms" in fails[0]


def test_subfloor_baseline_tolerates_jitter_but_gates_blowups(tmp_path):
    """The ISSUE-8 bugfix: sub-floor baselines are gated against
    floor_ms*factor, not skipped. 4ms -> 9ms passes (under the 10ms
    gate); 4ms -> 400ms fails."""
    res, base = _dirs(tmp_path, {"round_ms": 4.0}, {"round_ms": 9.0})
    assert check_regressions(res, base, factor=2.0, floor_ms=5.0) == []
    res, base = _dirs(tmp_path, {"round_ms": 4.0}, {"round_ms": 400.0})
    fails = check_regressions(res, base, factor=2.0, floor_ms=5.0)
    assert len(fails) == 1
    assert "gate 10.0ms" in fails[0]


def test_rate_fields_gate_on_drop(tmp_path):
    res, base = _dirs(tmp_path, {"eps_per_s": 40.0}, {"eps_per_s": 25.0})
    assert check_regressions(res, base, factor=2.0) == []
    res, base = _dirs(tmp_path, {"eps_per_s": 40.0}, {"eps_per_s": 10.0})
    assert len(check_regressions(res, base, factor=2.0)) == 1


def test_missing_fresh_file_is_a_failure(tmp_path):
    _write(tmp_path / "baselines", "BENCH_x_smoke.json", {"round_ms": 1.0})
    (tmp_path / "results").mkdir()
    fails = check_regressions(str(tmp_path / "results"),
                              str(tmp_path / "baselines"), factor=2.0)
    # the lone baseline has no fresh twin -> the missing-file failure
    # plus the zero-fields-compared (vacuous guard) failure
    assert any("missing" in f for f in fails)
    assert any("vacuous" in f for f in fails)


def test_zero_comparable_fields_is_a_failure(tmp_path):
    res, base = _dirs(tmp_path, {"final_acc": 0.9}, {"final_acc": 0.9})
    fails = check_regressions(res, base, factor=2.0)
    assert len(fails) == 1 and "vacuous" in fails[0]


def test_factor_env_override(tmp_path, monkeypatch):
    monkeypatch.setenv("BENCH_CHECK_FACTOR", "10.0")
    res, base = _dirs(tmp_path, {"round_ms": 10.0}, {"round_ms": 90.0})
    assert check_regressions(res, base) == []          # 9x < 10x
    monkeypatch.setenv("BENCH_CHECK_FACTOR", "2.0")
    assert len(check_regressions(res, base)) == 1


def test_async_engine_baseline_is_committed_and_guarded():
    """ISSUE 8: the async bench participates in the regression guard —
    its committed smoke baseline must expose timing fields."""
    base = (Path(__file__).resolve().parents[1] / "benchmarks" /
            "baselines" / "BENCH_async_engine_smoke.json")
    assert base.exists()
    fields = _perf_fields(json.loads(base.read_text()))
    times = [k for k, (_, kind) in fields.items() if kind == "time"]
    assert any("wall_per_round_ms" in k for k in times)
    assert any("sync_round_r50_ms" in k for k in times)


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
