"""Uplink update codecs: round-trip properties, error feedback, cost
accounting, and codec="none" parity across all three engines.

The parity contract is the PR's hard invariant: an inactive codec must
leave every engine's trace byte-identical to the uncompressed program
(the engines statically short-circuit), so compression can ship default-
off with zero regression risk. Active codecs are pinned on (a) the
stochastic-rounding/topk math itself, (b) the error-feedback residual
telescope, and (c) host-loop vs fused-scan lockstep (both derive their
codec keys from ``compression.round_key``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.data import make_dataset, partition_noniid

KEY = jax.random.PRNGKey(0)


def _cfg(codec, **kw):
    return comp.CompressionConfig(codec=codec, **kw)


# -------------------------------------------------------------- config

def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        comp.CompressionConfig(codec="gzip")
    with pytest.raises(ValueError):
        comp.CompressionConfig(codec="topk", topk_frac=0.0)


def test_config_is_hashable_static():
    a, b = _cfg("int8"), _cfg("int8")
    assert hash(a) == hash(b) and a == b
    assert not _cfg("none").active and _cfg("topk").active


# -------------------------------------------------- message accounting

def test_message_bits_none_is_raw_bytes():
    params = {"w": jnp.zeros((7, 11), jnp.float32),
              "b": jnp.zeros((11,), jnp.float32)}
    assert comp.message_bits(_cfg("none"), params) == (7 * 11 + 11) * 32


def test_message_bits_ratios():
    params = {"w": jnp.zeros((64, 256), jnp.float32)}
    raw = comp.message_bits(_cfg("none"), params)
    assert comp.message_bits(_cfg("bf16_delta"), params) == raw / 2
    # int8: 4x minus the per-leaf scale overhead
    int8 = comp.message_bits(_cfg("int8"), params)
    assert raw / int8 > 3.9
    # topk at 5%: > 4x despite charging value+index per kept entry
    topk = comp.message_bits(_cfg("topk", topk_frac=0.05), params)
    assert raw / topk > 4.0
    # denser topk costs more bits
    assert comp.message_bits(_cfg("topk", topk_frac=0.5), params) > topk


def test_round_msg_bits_helper():
    sp = cm.SystemParams(n_devices=10, n_edges=3)
    # default: sp.model_bits per message (the pre-codec accounting)
    assert cm.round_msg_bits(sp, 40, 3) == (40 + 3) * sp.model_bits
    # codec override prices every uplink with the compressed size
    assert cm.round_msg_bits(sp, 40, 3, msg_bits=100.0) == 4300.0


# --------------------------------------------------- codec round trips

def test_identity_passthrough_is_exact():
    """codec="none" must not even enter delta space: encode_decode hands
    the inputs back untouched (f32 ``a + (b - a) != b``, so a delta
    round-trip would break the engines' bitwise parity)."""
    delta = {"w": jax.random.normal(KEY, (4, 8))}
    resid = {"w": jnp.zeros((4, 8))}
    dec, nr = comp.encode_decode(_cfg("none"), KEY, delta, resid)
    assert dec is delta and nr is resid


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=200),
       st.floats(min_value=1e-3, max_value=10.0))
def test_int8_roundtrip_error_bounded_by_one_level(R, p, scale_mag):
    """Stochastic rounding lands on one of the two adjacent levels, so
    the per-element error is below one quantization step (≈ absmax/127),
    and the wire format really is int8."""
    x = scale_mag * jax.random.normal(jax.random.PRNGKey(R * 1000 + p),
                                      (R, p))
    q, sc = comp.encode_rows(_cfg("int8"), KEY, x)
    assert q.dtype == jnp.int8 and sc.shape == (R,)
    err = np.abs(np.asarray(comp.decode_rows(_cfg("int8"), q, sc) - x))
    assert err.max() <= np.asarray(sc).max() * (1 + 1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=2, max_value=5),
       st.integers(min_value=8, max_value=100))
def test_topk_keeps_largest_magnitudes(R, p):
    cfg = _cfg("topk", topk_frac=0.25)
    x = jax.random.normal(jax.random.PRNGKey(R * 77 + p), (R, p))
    q, sc = comp.encode_rows(cfg, KEY, x)
    k = comp._topk_k(cfg, p)
    qn = np.asarray(q)
    xn = np.abs(np.asarray(x))
    for r in range(R):
        kept = np.flatnonzero(qn[r])
        assert len(kept) == k
        # every kept entry >= every dropped entry (ties aside)
        dropped = np.setdiff1d(np.arange(p), kept)
        if len(dropped):
            assert xn[r, kept].min() >= xn[r, dropped].max() - 1e-6


def test_bf16_roundtrip_relative_error():
    x = jax.random.normal(KEY, (3, 50))
    q, sc = comp.encode_rows(_cfg("bf16_delta"), KEY, x)
    assert q.dtype == jnp.bfloat16
    dec = comp.decode_rows(_cfg("bf16_delta"), q, sc)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x),
                               rtol=1e-2, atol=1e-3)


@pytest.mark.parametrize("codec", ["bf16_delta", "int8", "topk"])
def test_error_feedback_bias_vanishes_over_rounds(codec):
    """The EF telescope: summing the decoded transmissions over R rounds
    of a constant true delta d gives R*d - resid_R, so the mean
    compressed update's bias is |resid_R|/R -> 0. Checked against the
    single-shot (no-EF) bias, which it must beat."""
    cfg = _cfg(codec, topk_frac=0.1)
    d = jax.random.normal(KEY, (3, 64))
    resid = jnp.zeros_like(d)
    R = 30
    total = np.zeros(d.shape, np.float64)
    for r in range(R):
        q, sc, resid = comp.encode_leaf(cfg, jax.random.PRNGKey(r), d,
                                        resid)
        total += np.asarray(comp.decode_rows(cfg, q, sc), np.float64)
    bias = np.abs(total / R - np.asarray(d, np.float64)).mean()
    # telescope bound: mean bias == |resid_R| / R elementwise
    np.testing.assert_allclose(bias,
                               np.abs(np.asarray(resid)).mean() / R,
                               rtol=1e-3, atol=1e-7)
    q1, sc1, _ = comp.encode_leaf(
        dataclasses.replace(cfg, error_feedback=False), KEY, d,
        jnp.zeros_like(d))
    one_shot = np.abs(
        np.asarray(comp.decode_rows(cfg, q1, sc1)) - np.asarray(d)).mean()
    if codec != "bf16_delta":        # bf16 cast is near-exact one-shot
        assert bias < one_shot


def test_round_key_deterministic_and_distinct():
    cfg = _cfg("int8", seed=3)
    k1 = comp.round_key(cfg, 7, 2)
    k2 = comp.round_key(cfg, 7, 2)
    assert np.array_equal(np.asarray(k1), np.asarray(k2))
    assert not np.array_equal(np.asarray(k1),
                              np.asarray(comp.round_key(cfg, 7, 3)))
    assert not np.array_equal(np.asarray(k1),
                              np.asarray(comp.round_key(cfg, 8, 2)))


# ------------------------------------------------------- engine parity

def _world(seed=0, N=8, M=3):
    sp = cm.SystemParams(n_devices=N, n_edges=M, d_range=(50, 90),
                         L=2, Q=2)
    pop = cm.sample_population(sp, seed=seed)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=240, n_test=100,
                                seed=seed)
    fed = partition_noniid(X, y, Xt, yt, n_devices=N, size_range=(20, 40),
                           seed=seed)
    return sp, pop, fed


@pytest.fixture(scope="module")
def world():
    return _world()


def test_framework_codec_none_is_bitwise_oracle(world):
    """round_step with codec="none" == the pre-codec fused engine,
    params bitwise and costs exactly equal."""
    from repro.core.framework import FrameworkConfig, HFLFramework
    sp, pop, fed = world

    def run(compression):
        cfg = FrameworkConfig(H=5, engine="fused", seed=0, alloc_steps=30,
                              compression=compression)
        fw = HFLFramework(sp, pop, fed, cfg)
        recs = [fw.run_round(i) for i in range(2)]
        return fw, recs

    fw_ref, recs_ref = run(comp.CompressionConfig())
    fw_none, recs_none = run(_cfg("none"))
    for a, b in zip(jax.tree.leaves(fw_ref.model_params),
                    jax.tree.leaves(fw_none.model_params)):
        assert bool((a == b).all())
    for ra_, rb in zip(recs_ref, recs_none):
        assert ra_["T_i"] == rb["T_i"] and ra_["E_i"] == rb["E_i"]
        assert ra_["msg_bits"] == rb["msg_bits"]
    assert recs_none[-1]["codec"] == "none"
    # per-cluster scheduling can round the cohort up past the requested
    # H, so size the expectation off the record's actual cohort
    assert recs_none[-1]["uplink_bytes"] * 8 == pytest.approx(
        sp.Q * recs_none[-1]["H"] * fw_none.uplink_bits)


def test_framework_compressed_round_cuts_msg_bits(world):
    """int8 end-to-end: training still progresses, msg_bits and the
    cost-model energy E_i drop with the compressed payload, and the EF
    residuals become non-zero."""
    from repro.core.framework import FrameworkConfig, HFLFramework
    sp, pop, fed = world

    def run(codec):
        cfg = FrameworkConfig(H=5, engine="fused", seed=0, alloc_steps=30,
                              compression=_cfg(codec))
        fw = HFLFramework(sp, pop, fed, cfg)
        recs = [fw.run_round(i) for i in range(2)]
        return fw, recs

    fw_n, recs_n = run("none")
    fw_c, recs_c = run("int8")
    assert recs_n[-1]["msg_bits"] / recs_c[-1]["msg_bits"] > 3.9
    # same channel realisations, smaller payload -> strictly cheaper round
    assert recs_c[-1]["E_i"] < recs_n[-1]["E_i"]
    assert recs_c[-1]["T_i"] < recs_n[-1]["T_i"]
    assert np.isfinite(recs_c[-1]["acc"])
    dev_resid, edge_resid = fw_c.codec_state
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree.leaves(dev_resid))


def test_sweep_codec_none_parity_and_compressed_lockstep(world):
    """SweepRunner: codec="none" reproduces the uncompressed sweep
    exactly; an active codec keeps host-loop, fused scan and the oracle
    host loop over the traced step in lockstep (same round_key stream)."""
    from repro.core.sweep import SweepRunner, build_scheduler
    sp, pop, fed = world
    _, pop1, fed1 = _world(seed=1)
    worlds = [(pop, fed), (pop1, fed1)]
    scheds = lambda: [build_scheduler("fedavg", f, sp, 4, seed=s)  # noqa: E731
                      for s, (_, f) in enumerate(worlds)]

    ref = SweepRunner(sp, worlds, alloc_steps=25).run(scheds(), 2)
    none = SweepRunner(sp, worlds, alloc_steps=25,
                       compression=_cfg("none")).run(scheds(), 2)
    assert np.array_equal(ref["acc"], none["acc"])
    assert ref["msg_bits_per_round"] == none["msg_bits_per_round"]

    r_c = SweepRunner(sp, worlds, alloc_steps=25,
                      compression=_cfg("int8"))
    host = r_c.run(scheds(), 2)
    fused = r_c.run(scheds(), 2, fused=True)
    oracle = r_c.run(scheds(), 2, fused="oracle")
    assert np.array_equal(host["acc"], fused["acc"])
    assert np.array_equal(oracle["acc"], fused["acc"])
    assert host["codec"] == "int8"
    assert ref["msg_bits_per_round"] / host["msg_bits_per_round"] > 3.9


def test_async_codec_none_parity_and_compressed_smoke(world):
    """AsyncHFLEngine: codec="none" is bitwise the pre-codec engine on a
    churny trace; int8 trains with ~4x smaller messages and streams the
    codec fields into its per-round record."""
    from repro.core.async_engine import AsyncConfig, AsyncHFLEngine
    sp, pop, fed = world
    ap = cm.AvailabilityParams(p_offline0=0.1, mean_up_s=900.0,
                               mean_down_s=120.0, straggler_frac=0.25,
                               straggler_scale=3.0)
    trace = cm.sample_availability(ap, pop.n_devices, seed=5)

    def run(compression):
        cfg = AsyncConfig(H=5, seed=0, alloc_steps=25, buffer_size=2,
                          compression=compression)
        eng = AsyncHFLEngine(sp, pop, fed, cfg, trace=trace)
        recs = [eng.step_round() for _ in range(2)]
        return eng, recs

    eng_ref, recs_ref = run(comp.CompressionConfig())
    eng_none, recs_none = run(_cfg("none"))
    for a, b in zip(jax.tree.leaves(eng_ref.model_params),
                    jax.tree.leaves(eng_none.model_params)):
        assert bool((a == b).all())
    assert recs_ref[-1]["msg_bits"] == recs_none[-1]["msg_bits"]

    eng_c, recs_c = run(_cfg("int8"))
    assert recs_c[-1]["codec"] == "int8"
    assert recs_none[-1]["msg_bits"] / recs_c[-1]["msg_bits"] > 3.9
    assert recs_c[-1]["uplink_bytes"] * 8 == pytest.approx(
        (recs_c[-1]["n_updates"] + pop.n_edges) * eng_c.uplink_bits)
    assert np.isfinite(recs_c[-1]["acc"])
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree.leaves(eng_c.dev_resid))


def test_sequential_engine_rejects_codec(world):
    from repro.core.framework import FrameworkConfig, HFLFramework
    sp, pop, fed = world
    with pytest.raises(ValueError, match="fused"):
        HFLFramework(sp, pop, fed,
                     FrameworkConfig(H=5, engine="sequential",
                                     compression=_cfg("int8")))
