"""Scheduling (Algs 3-4): constraints (15e)/(15f), cluster balance, and the
IKC no-repeat rotation property — with hypothesis over random clusterings."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.scheduling import FedAvgScheduler, IKCScheduler, VKCScheduler


def _clusters(rng, n, k):
    c = rng.integers(0, k, n)
    # ensure every cluster non-empty
    c[:k] = np.arange(k)
    return c


def test_fedavg_random_size_and_uniqueness():
    rng = np.random.default_rng(0)
    s = FedAvgScheduler(100, 30)
    for _ in range(5):
        sel = s.schedule(rng)
        assert len(sel) == 30
        assert len(set(sel.tolist())) == 30          # (15f): no duplicates
        assert sel.max() < 100 and sel.min() >= 0    # (15e): subset of N


@given(n=st.integers(30, 120), k=st.integers(2, 10), h=st.integers(1, 4),
       seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_vkc_properties(n, k, h, seed):
    rng = np.random.default_rng(seed)
    clusters = _clusters(rng, n, k)
    if h * k > n:
        return
    s = VKCScheduler(clusters, h)
    sel = s.schedule(rng)
    assert len(sel) == h * k
    assert len(set(sel.tolist())) == len(sel)
    # each cluster contributes min(h, |C_k|) at least
    for kk in range(k):
        got = sum(1 for d in sel if clusters[d] == kk)
        assert got >= min(h, int((clusters == kk).sum()))


@given(n=st.integers(30, 120), k=st.integers(2, 10), h=st.integers(1, 3),
       seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_ikc_properties(n, k, h, seed):
    rng = np.random.default_rng(seed)
    clusters = _clusters(rng, n, k)
    if h * k > n:
        return
    s = IKCScheduler(clusters, h)
    for _ in range(6):
        sel = s.schedule(rng)
        assert len(sel) == h * k
        assert len(set(sel.tolist())) == len(sel)


def test_ikc_rotates_before_repeating():
    """Every cluster member must be scheduled once before any member is
    scheduled twice (the paper's G_k bookkeeping)."""
    rng = np.random.default_rng(7)
    k, per, h = 4, 6, 2
    clusters = np.repeat(np.arange(k), per)          # 4 clusters x 6 devices
    s = IKCScheduler(clusters, h)
    counts = np.zeros(len(clusters), int)
    rounds_to_cover = per // h                       # 3 rounds covers all
    for _ in range(rounds_to_cover):
        sel = s.schedule(rng)
        counts[sel] += 1
    assert counts.max() == 1 and counts.min() == 1, counts


def test_ikc_beats_vkc_on_coverage():
    """After R rounds, IKC must have touched >= as many unique devices."""
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(1)
    clusters = np.repeat(np.arange(10), 10)
    ikc = IKCScheduler(clusters, 2)
    vkc = VKCScheduler(clusters, 2)
    seen_i, seen_v = set(), set()
    for _ in range(4):
        seen_i.update(ikc.schedule(rng1).tolist())
        seen_v.update(vkc.schedule(rng2).tolist())
    assert len(seen_i) >= len(seen_v)
    assert len(seen_i) == 80                         # 4 rounds x 20, no repeat


def test_small_cluster_topup():
    """Cluster smaller than h: all members scheduled + top-up keeps H."""
    rng = np.random.default_rng(3)
    clusters = np.array([0] * 2 + [1] * 28)          # cluster 0 has 2 < h=3
    s = IKCScheduler(clusters, 3)
    sel = s.schedule(rng)
    assert len(sel) == 6
    assert {0, 1} <= set(sel.tolist())
