"""Optional-hypothesis shim: property tests run everywhere.

Four seed-suite modules hard-imported ``hypothesis``, which is not in
the container, so they failed *collection* and took the whole tier-1
run down (`pytest -x`). Importing ``given``/``settings``/``st`` from
here uses the real hypothesis when installed and otherwise falls back
to a minimal deterministic stand-in: each ``@given`` test is executed
``max_examples`` times with values drawn from the declared strategies
via a fixed-seed numpy Generator. The fallback covers exactly the
strategy surface the suite uses (floats / integers / lists); extend it
here if a test needs more.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import types

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _lists(elements: _Strategy, min_size: int = 0, max_size: int = 10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    st = types.SimpleNamespace(floats=_floats, integers=_integers,
                               lists=_lists, sampled_from=_sampled_from,
                               booleans=_booleans)

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # resolved at call time so @settings works in either
                # decorator order (above @given it lands on the wrapper)
                n_examples = getattr(
                    wrapper, "_shim_max_examples",
                    getattr(fn, "_shim_max_examples",
                            _DEFAULT_MAX_EXAMPLES))
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    drawn_pos = [s.draw(rng) for s in pos_strategies]
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn_pos, **{**kwargs, **drawn_kw})

            # hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis exposes a zero-arg wrapper too)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
