"""Sharded SweepRunner: parity against the single-device vmapped oracle.

The multi-device cases run in spawned subprocesses (``multidevice``
fixture) because ``--xla_force_host_platform_device_count`` must be set
before jax import: each ``_payload_*`` function below is executed in a
fresh interpreter with 8 emulated CPU devices and asserts parity
internally (exit code carries the verdict). Lane independence makes the
two paths float-identical per round up to XLA partitioning
reassociation (~1 ulp/round on params, measured), which compounds
through training — so params/costs compare tightly, test-set accuracy
with a couple-of-samples tolerance, and early-stop targets sit ≥3
test-samples away from the per-round accuracies they gate.

The geo payload (non-slow) doubles as tier-1's sharding smoke — one
subprocess per run, ~40 s; the hfel/drl/chunked payloads are marked
slow and run in the weekly sharded-parity CI lane. Single-device cases
(1-lane mesh plumbing, lane_chunk parity, the done-mask freeze
property) run inline in tier-1.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

# world shared by all payloads: small enough that both engines compile
# in seconds, big enough that lanes diverge (per-lane model inits).
_N, _M, _H = 12, 3, 8
_ROUNDS = 4
_TARGET = 0.35


def _make_world():
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_dataset, partition_noniid

    sp = SystemParams(n_devices=_N, n_edges=_M)
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=240, n_test=60,
                                seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=_N,
                           size_range=(10, 16), seed=0)
    return sp, pop, fed


def _run_one(S, assign, shard, n_rounds=_ROUNDS, target_acc=_TARGET,
             shard_kw=None, **run_kw):
    """One sweep through either engine (sharded runner asserted to pad S
    up to the emulated device count). shard_kw: extra ctor kwargs for
    the sharded runner only (e.g. lane_chunk)."""
    import jax

    from repro.core.sweep import SweepRunner, build_scheduler

    sp, pop, fed = _make_world()
    worlds = [(pop, fed)] * S
    runner = SweepRunner(sp, worlds, lr=0.02, alloc_steps=25,
                         model_seed=0, shard=shard,
                         **(shard_kw if shard and shard_kw else {}))
    if shard:
        n_dev = len(jax.devices())
        assert runner.S_pad == -(-S // n_dev) * n_dev, (
            runner.S_pad, S, n_dev)
    scheds = [build_scheduler("fedavg", fed, sp, _H, seed=s)
              for s in range(S)]
    a = assign() if callable(assign) else assign
    return runner.run(scheds, n_rounds, assign=a, seeds=list(range(S)),
                      target_acc=target_acc, **run_kw)


def _run_pair(S, assign, n_rounds=_ROUNDS, target_acc=_TARGET,
              shard_kw=None, **run_kw):
    """Run the same sweep through the single-device and sharded engines
    and return both result dicts."""
    return [_run_one(S, assign, shard, n_rounds=n_rounds,
                     target_acc=target_acc, shard_kw=shard_kw, **run_kw)
            for shard in (False, True)]


def _assert_parity(o0, o1, acc_atol=0.09):
    """Allclose parity between the vmapped oracle (o0) and the sharded
    run (o1). Round costs depend only on (sched, assign, done) — all
    host-side and parity-exact — so T/E/obj compare tightly and FIRST;
    accuracy rides the trained params, where XLA partitioning drift
    (~1 ulp/round) amplifies chaotically through training, so it
    tolerates a few flipped test samples."""
    assert o0["acc"].shape == o1["acc"].shape
    np.testing.assert_array_equal(o0["iters"], o1["iters"])
    for k in ("T_i", "E_i", "obj"):
        np.testing.assert_allclose(o0[k], o1[k], rtol=1e-4, atol=1e-6,
                                   err_msg=k)
    np.testing.assert_allclose(o0["acc"], o1["acc"], atol=acc_atol)
    assert o0["H"] == o1["H"]


# ------------------------------------------------- multidevice payloads

def _payload_geo():
    """Geo assignment, S=5 lanes on 8 devices (non-divisible: 3 dead pad
    lanes) with per-lane early stop firing at different rounds.

    The early-stop target is picked from a no-stop probe of the oracle
    rather than hardcoded: pre-stop trajectories are identical across
    engines, so under a target t every lane stops at the first probe
    round with acc >= t — choosing the candidate threshold with the
    largest margin to every probe accuracy (while still making lanes
    stop at different rounds) keeps the iters-equality assert off the
    knife edge where tolerated float drift could flip a stopping round.
    """
    import jax

    assert len(jax.devices()) == 8, jax.devices()
    probe = _run_one(5, "geo", shard=False, target_acc=None)
    accs = probe["acc"]                                  # (S, R)
    vals = np.unique(accs)
    best, best_margin, best_iters = None, 0.0, None
    for t in (vals[:-1] + vals[1:]) / 2:
        reached = accs >= t
        iters = np.where(reached.any(axis=1),
                         reached.argmax(axis=1) + 1, _ROUNDS)
        if iters.min() < _ROUNDS and len(set(iters.tolist())) > 1:
            margin = float(np.abs(accs - t).min())
            if margin > best_margin:
                best, best_margin, best_iters = float(t), margin, iters
    assert best is not None, f"no divergent early-stop target in {accs}"
    assert best_margin >= 0.04, (best, best_margin, accs)

    o0, o1 = _run_pair(5, "geo", target_acc=best)
    _assert_parity(o0, o1, acc_atol=min(0.09, best_margin))
    # the early stop actually exercised per-lane divergence, exactly as
    # the probe predicted
    np.testing.assert_array_equal(o0["iters"], best_iters)


def _payload_hfel():
    """Batched K-candidate HFEL search as the per-round assigner (host
    search between sharded rounds), S=3 on 8 devices. No early-stop
    target: search/allocation parity is exact, and keeping every lane
    live avoids threshold knife-edges on the chaotic accuracy (the geo
    payload owns early-stop coverage)."""

    def make_assign():
        from repro.core.sweep import make_hfel_assign

        sp, _, _ = _make_world()
        return make_hfel_assign(sp, n_transfer=6, n_exchange=6,
                                alloc_steps=25, n_candidates=4)

    o0, o1 = _run_pair(3, make_assign, n_rounds=2, target_acc=None)
    _assert_parity(o0, o1, acc_atol=0.15)


def _payload_drl():
    """Greedy D3QN deployment assigner (jitted Q eval on the default
    device between sharded rounds), S=3 on 8 devices. Untrained-net
    assignments are deterministic, so like the hfel payload this skips
    the early-stop target and leans on exact cost parity."""
    import jax

    def make_assign():
        from repro.core.sweep import make_drl_assign
        from repro.drl.d3qn import d3qn_init
        from repro.drl.train import drl_features

        sp, pop, _ = _make_world()
        feats = drl_features(pop, np.arange(_H))
        params = d3qn_init(jax.random.PRNGKey(0), feats.shape[-1], _M)
        return make_drl_assign(sp, params)

    o0, o1 = _run_pair(3, make_assign, n_rounds=2, target_acc=None)
    _assert_parity(o0, o1, acc_atol=0.15)


def _payload_geo_chunked():
    """lane_chunk=1 cache-blocked execution inside the sharded blocks
    (the bench's fastest CPU variant) against the plain vmapped
    single-device oracle."""
    o0, o1 = _run_pair(5, "geo", shard_kw={"lane_chunk": 1})
    _assert_parity(o0, o1)


# ------------------------------------------------------------ the tests

@pytest.mark.multidevice
def test_sharded_parity_geo_nondivisible_early_stop(multidevice):
    multidevice("test_sweep_shard:_payload_geo")


@pytest.mark.multidevice
@pytest.mark.slow
def test_sharded_parity_hfel(multidevice):
    multidevice("test_sweep_shard:_payload_hfel")


@pytest.mark.multidevice
@pytest.mark.slow
def test_sharded_parity_lane_chunked(multidevice):
    multidevice("test_sweep_shard:_payload_geo_chunked")


@pytest.mark.multidevice
@pytest.mark.slow
def test_sharded_parity_drl(multidevice):
    multidevice("test_sweep_shard:_payload_drl")


def test_shard_single_device_mesh_matches_vmap(small_world):
    """shard=True on a 1-device ('lane',) mesh is the same program
    modulo shard_map plumbing — exact parity, runs in tier-1 without
    emulation (S_pad == S, no dead lanes)."""
    from repro.core.sweep import SweepRunner, build_scheduler
    from repro.launch.mesh import sweep_mesh

    sp, pop, fed = small_world
    worlds = [(pop, fed)] * 2
    outs = []
    for shard in (False, True):
        runner = SweepRunner(sp, worlds, lr=0.02, alloc_steps=20,
                             model_seed=0, shard=shard,
                             mesh=sweep_mesh(1) if shard else None)
        scheds = [build_scheduler("fedavg", fed, sp, 6, seed=s)
                  for s in range(2)]
        outs.append(runner.run(scheds, 2, assign="geo", seeds=[0, 1],
                               target_acc=0.9))
    _assert_parity(outs[0], outs[1], acc_atol=1e-6)


def test_lane_chunk_matches_vmap(small_world):
    """Single-device lane_chunk=1 (sequential lax.map over lanes) is the
    same per-lane computation as the whole-axis vmap — parity to float
    reassociation, runs in tier-1."""
    from repro.core.sweep import SweepRunner, build_scheduler

    sp, pop, fed = small_world
    worlds = [(pop, fed)] * 2
    outs = []
    for chunk in (None, 1):
        runner = SweepRunner(sp, worlds, lr=0.02, alloc_steps=20,
                             model_seed=0, lane_chunk=chunk)
        scheds = [build_scheduler("fedavg", fed, sp, 6, seed=s)
                  for s in range(2)]
        outs.append(runner.run(scheds, 2, assign="geo", seeds=[0, 1]))
    _assert_parity(outs[0], outs[1], acc_atol=0.05)


def test_sweep_mesh_shape_and_validation():
    from repro.core.sweep import SweepRunner
    from repro.launch.mesh import make_debug_mesh, sweep_mesh
    from repro.parallel.sharding import pad_lanes

    mesh = sweep_mesh()
    assert mesh.axis_names == ("lane",)
    with pytest.raises(ValueError):
        sweep_mesh(10_000)
    assert pad_lanes(5, 8) == 8
    assert pad_lanes(8, 8) == 8
    assert pad_lanes(9, 8) == 16
    assert pad_lanes(1, 1) == 1
    # a non-lane mesh is rejected up front
    sp, pop, fed = _make_world()
    with pytest.raises(ValueError):
        SweepRunner(sp, [(pop, fed)], shard=True,
                    mesh=make_debug_mesh())
    # lane_chunk must divide the per-device lane block
    with pytest.raises(ValueError):
        SweepRunner(sp, [(pop, fed)] * 2, lane_chunk=3)


# -------------------------------------- done-mask freeze (property test)

_world_cache = {}


def _cached_sweep_inputs():
    """One tiny compiled-once sweep_round input set for the freeze
    property (module-level cache: the shim draws ~20 examples)."""
    if _world_cache:
        return _world_cache["inputs"]
    import dataclasses

    import jax.numpy as jnp

    from repro.core.sweep import SweepRunner, build_scheduler

    sp, pop, fed = _make_world()
    runner = SweepRunner(sp, [(pop, fed)] * 3, lr=0.02, alloc_steps=20,
                         model_seed=0)
    sched = np.stack([np.asarray(
        build_scheduler("fedavg", fed, sp, _H, seed=s).schedule(
            np.random.default_rng(s)))
        for s in range(3)])
    assign = sched % _M
    spp = dataclasses.replace(sp, model_bits=float(runner.model_bits))
    _world_cache["inputs"] = (runner, spp, jnp.asarray(sched),
                              jnp.asarray(assign))
    return _world_cache["inputs"]


@settings(max_examples=8, deadline=None)
@given(mask_bits=st.integers(min_value=1, max_value=6),
       n_rounds=st.integers(min_value=1, max_value=2))
def test_done_mask_freeze_invariant(mask_bits, n_rounds):
    """Frozen lanes are *exactly* constant: across any subsequent
    rounds, a done lane's params are bitwise-unchanged and its per-round
    T_i/E_i are exactly zero, while at least one live lane trains."""
    import jax
    import jax.numpy as jnp

    from repro.core.sweep import sweep_round

    runner, spp, sched, assign = _cached_sweep_inputs()
    done = np.array([(mask_bits >> i) & 1 == 1 for i in range(3)])
    params = runner.params0
    for _ in range(n_rounds):
        new_params, (T_i, E_i) = sweep_round(
            runner.apply_fn, spp, params, runner.u_b, runner.D_b,
            runner.p_b, runner.g_b, runner.g_cloud_b, runner.B_m_b,
            runner.X_b, runner.y_b, runner.mask_b, runner.D_b, sched,
            assign, 0.02, M=_M, L=spp.L, Q=spp.Q, alloc_steps=20,
            done_b=jnp.asarray(done))
        for old, new in zip(jax.tree.leaves(params),
                            jax.tree.leaves(new_params)):
            np.testing.assert_array_equal(np.asarray(old)[done],
                                          np.asarray(new)[done])
            if not done.all():
                assert not np.array_equal(np.asarray(old)[~done],
                                          np.asarray(new)[~done])
        assert np.all(np.asarray(T_i)[done] == 0.0)
        assert np.all(np.asarray(E_i)[done] == 0.0)
        assert np.all(np.asarray(T_i)[~done] > 0.0)
        params = new_params
