"""End-to-end behaviour of the paper's system (Algorithm 6 framework).

Uses the session-scoped ``small_world`` fixture from conftest.py."""
import numpy as np
import pytest

from repro.core.framework import FrameworkConfig, HFLFramework


@pytest.mark.slow
def test_framework_round_records_costs(small_world):
    sp, pop, fed = small_world
    cfg = FrameworkConfig(scheduler="ikc", assigner="geo", H=10, K=10,
                          target_acc=0.99, max_iters=2, alloc_steps=80,
                          seed=0)
    fw = HFLFramework(sp, pop, fed, cfg)
    # clustering quality on the synthetic non-IID split must be high
    assert fw.clustering_stats["ari"] >= 0.6
    assert fw.clustering_stats["delay_s"] > 0
    rec = fw.run_round(1)
    assert rec["T_i"] > 0 and rec["E_i"] > 0
    assert rec["obj_i"] == pytest.approx(rec["E_i"] + sp.lam * rec["T_i"])
    assert rec["msg_bits"] == pytest.approx(
        (sp.Q * 10 + pop.n_edges) * fw.sp.model_bits)
    assert 0 <= rec["acc"] <= 1
    s = fw.summary()
    assert s["iters"] == 1 and s["objective"] > 0


@pytest.mark.slow
def test_scheduler_variants_construct(small_world):
    sp, pop, fed = small_world
    for sched in ("fedavg", "vkc"):
        cfg = FrameworkConfig(scheduler=sched, assigner="geo", H=10, K=10,
                              max_iters=1, alloc_steps=60, seed=1)
        fw = HFLFramework(sp, pop, fed, cfg)
        sel = fw.scheduler.schedule(np.random.default_rng(0))
        assert len(sel) == 10
        assert len(set(sel.tolist())) == 10


@pytest.mark.slow
def test_ikc_clustering_cheaper_than_vkc(small_world):
    """Table II: IKC's mini-model clustering must cost far less time and
    energy than VKC's full-model clustering."""
    sp, pop, fed = small_world
    f_ikc = HFLFramework(sp, pop, fed, FrameworkConfig(
        scheduler="ikc", assigner="geo", H=10, max_iters=1, seed=2))
    f_vkc = HFLFramework(sp, pop, fed, FrameworkConfig(
        scheduler="vkc", assigner="geo", H=10, max_iters=1, seed=2))
    assert (f_ikc.clustering_stats["energy_j"]
            < 0.25 * f_vkc.clustering_stats["energy_j"])
    assert (f_ikc.clustering_stats["delay_s"]
            < 0.25 * f_vkc.clustering_stats["delay_s"])
    assert f_ikc.clustering_stats["ari"] >= 0.6
    assert f_vkc.clustering_stats["ari"] >= 0.6
