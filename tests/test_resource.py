"""Resource allocation (problem 27): optimality vs grid search, feasibility."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import cost_model as cm
from repro.core import resource as ra

SP = cm.SystemParams()
POP = cm.sample_population(SP, seed=1)


def _edge_inputs(n, edge=0):
    idx = jnp.arange(n)
    return (POP.u[idx], POP.D[idx], POP.p[idx], POP.g[idx, edge],
            POP.B_m[edge], jnp.ones(n, bool))


def test_feasibility():
    u, D, p, g, B, mask = _edge_inputs(8)
    res = ra.allocate(SP, u, D, p, g, B, mask)
    assert float(jnp.sum(res.b)) <= float(B) * (1 + 1e-4)
    assert float(jnp.max(res.f)) <= SP.f_max * (1 + 1e-6)
    assert float(jnp.min(res.f)) > 0
    assert float(res.obj) > 0


def test_beats_or_matches_uniform():
    for n in (2, 5, 10):
        u, D, p, g, B, mask = _edge_inputs(n)
        opt = ra.allocate(SP, u, D, p, g, B, mask)
        uni = ra.allocate_uniform(SP, u, D, p, g, B, mask)
        assert float(opt.obj) <= float(uni.obj) * 1.02


def test_matches_grid_search_two_devices():
    u, D, p, g, B, mask = _edge_inputs(2)
    res = ra.allocate(SP, u, D, p, g, B, mask)
    best = np.inf
    for x in np.linspace(0.02, 0.98, 49):
        b = jnp.array([x * float(B), (1 - x) * float(B)])
        for f1 in np.linspace(0.05, 1.0, 24):
            for f2 in np.linspace(0.05, 1.0, 24):
                f = jnp.array([f1, f2]) * SP.f_max
                t = cm.t_cmp(SP, u, D, f) + cm.t_com(SP, b, g, p)
                e = cm.e_cmp(SP, u, D, f) + cm.e_com(SP, b, g, p)
                obj = SP.Q * float(e.sum()) + SP.lam * SP.Q * float(t.max())
                best = min(best, obj)
    # within 2% of (coarse) grid optimum
    assert float(res.obj) <= best * 1.02


def test_mask_excludes_devices():
    u, D, p, g, B, _ = _edge_inputs(6)
    mask = jnp.array([True, True, True, False, False, False])
    res = ra.allocate(SP, u, D, p, g, B, mask)
    # bandwidth effectively goes to masked-in devices only
    assert float(jnp.sum(jnp.where(mask, res.b, 0.0))) >= 0.99 * float(jnp.sum(res.b))


def test_empty_edge_zero_objective():
    u, D, p, g, B, _ = _edge_inputs(4)
    res = ra.allocate(SP, u, D, p, g, B, jnp.zeros(4, bool))
    assert float(res.obj) == 0.0


def test_lambda_tradeoff():
    """Higher λ should never increase the optimised delay T_edge."""
    import dataclasses
    u, D, p, g, B, mask = _edge_inputs(8)
    sp_lo = dataclasses.replace(SP, lam=0.1)
    sp_hi = dataclasses.replace(SP, lam=10.0)
    t_lo = float(ra.allocate(sp_lo, u, D, p, g, B, mask).T_edge)
    t_hi = float(ra.allocate(sp_hi, u, D, p, g, B, mask).T_edge)
    assert t_hi <= t_lo * 1.05


def test_flatten_unflatten_trials_roundtrip():
    """The flat (K*E, H) trial layout solves each trial's edges exactly
    as an independent per-trial batch would."""
    K, E, H = 3, 2, 6
    rng = np.random.default_rng(0)
    idx = jnp.arange(H)
    u = jnp.broadcast_to(POP.u[idx], (K, E, H))
    D = jnp.broadcast_to(POP.D[idx], (K, E, H))
    p = jnp.broadcast_to(POP.p[idx], (K, E, H))
    g = jnp.broadcast_to(POP.g[idx, 0], (K, E, H))
    B = jnp.broadcast_to(POP.B_m[:E], (K, E))
    mask = jnp.asarray(rng.random((K, E, H)) < 0.6)
    flat = ra.flatten_trials(u, D, p, g, B, mask)
    assert flat[0].shape == (K * E, H)
    assert flat[4].shape == (K * E,)
    assert flat[5].shape == (K * E, H)
    res = ra.unflatten_trials(ra.allocate_batch(SP, *flat, steps=40), K, E)
    assert res.T_edge.shape == (K, E)
    assert res.b.shape == (K, E, H)
    for k in range(K):
        ref = ra.allocate_batch(SP, u[k], D[k], p[k], g[k], B[k], mask[k],
                                steps=40)
        np.testing.assert_allclose(np.asarray(res.T_edge[k]),
                                   np.asarray(ref.T_edge), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(res.b[k]),
                                   np.asarray(ref.b), rtol=1e-5)


def test_flatten_trials_extras_flattened_alongside():
    K, E, H = 2, 2, 4
    mask = jnp.ones((K, E, H), bool)
    zeros = jnp.zeros((K, E, H))
    B = jnp.ones((K, E))
    *_, tb, tf = ra.flatten_trials(zeros, zeros, zeros, zeros, B, mask,
                                   zeros, zeros + 1.0)
    assert tb.shape == (K * E, H)
    assert float(tf.min()) == 1.0


def test_warm_solver_neutral_start_matches_cold():
    """allocate_batch_warm from the neutral iterates is the cold solve."""
    u, D, p, g, B, mask = _edge_inputs(6)
    batch = lambda a: jnp.broadcast_to(a, (2,) + a.shape)  # noqa: E731
    args = (batch(u), batch(D), batch(p), batch(g),
            jnp.broadcast_to(B, (2,)), batch(mask))
    cold = ra.allocate_batch(SP, *args, steps=60)
    warm, (tb, tf) = ra.allocate_batch_warm(
        SP, *args, jnp.zeros((2, 6)), jnp.ones((2, 6)), steps=60)
    np.testing.assert_allclose(np.asarray(warm.obj), np.asarray(cold.obj),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(warm.b), np.asarray(cold.b),
                               rtol=1e-5)
    assert tb.shape == (2, 6) and tf.shape == (2, 6)
    # restarting from the final iterates stays at the optimum
    warm2, _ = ra.allocate_batch_warm(SP, *args, tb, tf, steps=20)
    assert float(warm2.obj[0]) <= float(cold.obj[0]) * 1.01


def test_masked_allocation_is_finite():
    """Regression: grad(logsumexp(-inf)) NaN + f32 underflow of (N0*b)^2
    in the rate VJP used to poison every masked allocation."""
    u, D, p, g, B, _ = _edge_inputs(10)
    mask = jnp.asarray(np.arange(10) % 3 == 0)
    res = ra.allocate(SP, u, D, p, g, B, mask)
    assert np.isfinite(float(res.obj))
    assert not np.isnan(np.asarray(res.b)).any()
    assert not np.isnan(np.asarray(res.f)).any()
    uni = ra.allocate_uniform(SP, u, D, p, g, B, mask)
    assert float(res.obj) <= float(uni.obj) * 1.02


# -------------------------------------- trial-layout property tests

@settings(max_examples=15, deadline=None)
@given(K=st.integers(min_value=1, max_value=5),
       E=st.integers(min_value=1, max_value=3),
       H=st.integers(min_value=1, max_value=8),
       seed=st.integers(min_value=0, max_value=10_000))
def test_flatten_trials_roundtrip_property(K, E, H, seed):
    """For ANY trial-major shape: flat row k*E+e is exactly trial k's
    edge e, and ``unflatten_trials`` is the bitwise inverse of
    ``flatten_trials`` on every AllocResult field (the HFEL search and
    the DRL wave engine both lean on this layout invariant)."""
    rng = np.random.default_rng(seed)
    u, D, p, g, extra = (jnp.asarray(rng.random((K, E, H)))
                         for _ in range(5))
    B = jnp.asarray(rng.random((K, E)))
    mask = jnp.asarray(rng.random((K, E, H)) < 0.5)
    fu, fD, fp, fg, fB, fmask, fextra = ra.flatten_trials(
        u, D, p, g, B, mask, extra)
    assert fu.shape == (K * E, H) and fB.shape == (K * E,)
    assert fextra.shape == (K * E, H)
    for k in range(K):
        for e in range(E):
            row = k * E + e
            np.testing.assert_array_equal(np.asarray(fu[row]),
                                          np.asarray(u[k, e]))
            np.testing.assert_array_equal(np.asarray(fmask[row]),
                                          np.asarray(mask[k, e]))
            assert float(fB[row]) == float(B[k, e])
    res = ra.AllocResult(b=fu, f=fD, T_edge=fB, E_edge=fB, obj=fB)
    tri = ra.unflatten_trials(res, K, E)
    np.testing.assert_array_equal(np.asarray(tri.b), np.asarray(u))
    np.testing.assert_array_equal(np.asarray(tri.f), np.asarray(D))
    np.testing.assert_array_equal(np.asarray(tri.T_edge), np.asarray(B))
    np.testing.assert_array_equal(np.asarray(tri.obj), np.asarray(B))
