"""Resource allocation (problem 27): optimality vs grid search, feasibility."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import resource as ra

SP = cm.SystemParams()
POP = cm.sample_population(SP, seed=1)


def _edge_inputs(n, edge=0):
    idx = jnp.arange(n)
    return (POP.u[idx], POP.D[idx], POP.p[idx], POP.g[idx, edge],
            POP.B_m[edge], jnp.ones(n, bool))


def test_feasibility():
    u, D, p, g, B, mask = _edge_inputs(8)
    res = ra.allocate(SP, u, D, p, g, B, mask)
    assert float(jnp.sum(res.b)) <= float(B) * (1 + 1e-4)
    assert float(jnp.max(res.f)) <= SP.f_max * (1 + 1e-6)
    assert float(jnp.min(res.f)) > 0
    assert float(res.obj) > 0


def test_beats_or_matches_uniform():
    for n in (2, 5, 10):
        u, D, p, g, B, mask = _edge_inputs(n)
        opt = ra.allocate(SP, u, D, p, g, B, mask)
        uni = ra.allocate_uniform(SP, u, D, p, g, B, mask)
        assert float(opt.obj) <= float(uni.obj) * 1.02


def test_matches_grid_search_two_devices():
    u, D, p, g, B, mask = _edge_inputs(2)
    res = ra.allocate(SP, u, D, p, g, B, mask)
    best = np.inf
    for x in np.linspace(0.02, 0.98, 49):
        b = jnp.array([x * float(B), (1 - x) * float(B)])
        for f1 in np.linspace(0.05, 1.0, 24):
            for f2 in np.linspace(0.05, 1.0, 24):
                f = jnp.array([f1, f2]) * SP.f_max
                t = cm.t_cmp(SP, u, D, f) + cm.t_com(SP, b, g, p)
                e = cm.e_cmp(SP, u, D, f) + cm.e_com(SP, b, g, p)
                obj = SP.Q * float(e.sum()) + SP.lam * SP.Q * float(t.max())
                best = min(best, obj)
    # within 2% of (coarse) grid optimum
    assert float(res.obj) <= best * 1.02


def test_mask_excludes_devices():
    u, D, p, g, B, _ = _edge_inputs(6)
    mask = jnp.array([True, True, True, False, False, False])
    res = ra.allocate(SP, u, D, p, g, B, mask)
    # bandwidth effectively goes to masked-in devices only
    assert float(jnp.sum(jnp.where(mask, res.b, 0.0))) >= 0.99 * float(jnp.sum(res.b))


def test_empty_edge_zero_objective():
    u, D, p, g, B, _ = _edge_inputs(4)
    res = ra.allocate(SP, u, D, p, g, B, jnp.zeros(4, bool))
    assert float(res.obj) == 0.0


def test_lambda_tradeoff():
    """Higher λ should never increase the optimised delay T_edge."""
    import dataclasses
    u, D, p, g, B, mask = _edge_inputs(8)
    sp_lo = dataclasses.replace(SP, lam=0.1)
    sp_hi = dataclasses.replace(SP, lam=10.0)
    t_lo = float(ra.allocate(sp_lo, u, D, p, g, B, mask).T_edge)
    t_hi = float(ra.allocate(sp_hi, u, D, p, g, B, mask).T_edge)
    assert t_hi <= t_lo * 1.05


def test_masked_allocation_is_finite():
    """Regression: grad(logsumexp(-inf)) NaN + f32 underflow of (N0*b)^2
    in the rate VJP used to poison every masked allocation."""
    u, D, p, g, B, _ = _edge_inputs(10)
    mask = jnp.asarray(np.arange(10) % 3 == 0)
    res = ra.allocate(SP, u, D, p, g, B, mask)
    assert np.isfinite(float(res.obj))
    assert not np.isnan(np.asarray(res.b)).any()
    assert not np.isnan(np.asarray(res.f)).any()
    uni = ra.allocate_uniform(SP, u, D, p, g, B, mask)
    assert float(res.obj) <= float(uni.obj) * 1.02
