"""Device assignment: HFEL search improves the objective; baselines valid."""
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.assignment import GeoAssigner, HFELAssigner
from repro.core.assignment.hfel import total_objective

SP = cm.SystemParams(n_devices=20, n_edges=4)
POP = cm.sample_population(SP, seed=5)
SCHED = np.arange(20)


def test_geo_assigns_nearest_edge():
    a, _ = GeoAssigner(SP).assign(POP, SCHED)
    assert a.shape == (20,)
    d = np.linalg.norm(POP.dev_pos[:, None] - POP.edge_pos[None], axis=-1)
    assert np.array_equal(a, d.argmin(axis=1))


@pytest.mark.parametrize("search", ["serial", "batched"])
def test_hfel_improves_over_geo_init(search):
    rng = np.random.default_rng(0)
    geo, _ = GeoAssigner(SP).assign(POP, SCHED)
    j_geo, _, _ = total_objective(SP, POP, SCHED, geo, alloc_steps=120)
    hfel = HFELAssigner(SP, n_transfer=40, n_exchange=80, alloc_steps=120,
                        search=search)
    a, j_hfel = hfel.assign(POP, SCHED, rng)
    assert a.shape == (20,)
    assert set(a.tolist()) <= set(range(SP.n_edges))    # (15f) valid edges
    assert j_hfel <= j_geo * 1.001


@pytest.mark.parametrize("search", ["serial", "batched"])
def test_hfel_objective_matches_total_objective(search):
    rng = np.random.default_rng(1)
    hfel = HFELAssigner(SP, n_transfer=20, n_exchange=30, alloc_steps=120,
                        search=search)
    a, j = hfel.assign(POP, SCHED, rng)
    j2, T_m, E_m = total_objective(SP, POP, SCHED, a, alloc_steps=120)
    assert j == pytest.approx(j2, rel=0.05)
    assert np.all(T_m >= 0) and np.all(E_m >= 0)


def test_batched_quality_not_worse_than_serial():
    """Parity: at the same seed and trial budget, the K-candidate engine
    reaches an objective no worse than the serial oracle's."""
    ser = HFELAssigner(SP, n_transfer=40, n_exchange=80, alloc_steps=120,
                       search="serial")
    bat = HFELAssigner(SP, n_transfer=40, n_exchange=80, alloc_steps=120,
                       search="batched")
    for seed in (0, 1, 2):
        _, j_ser = ser.assign(POP, SCHED, np.random.default_rng(seed))
        _, j_bat = bat.assign(POP, SCHED, np.random.default_rng(seed))
        assert j_bat <= j_ser * 1.01


def test_unknown_search_engine_raises():
    hfel = HFELAssigner(SP, n_transfer=5, n_exchange=5, search="magic")
    with pytest.raises(ValueError, match="search engine"):
        hfel.assign(POP, SCHED, np.random.default_rng(0))


def test_more_search_never_worse():
    rng1, rng2 = np.random.default_rng(2), np.random.default_rng(2)
    short = HFELAssigner(SP, n_transfer=10, n_exchange=10, alloc_steps=100)
    long_ = HFELAssigner(SP, n_transfer=60, n_exchange=120, alloc_steps=100)
    _, j_short = short.assign(POP, SCHED, rng1)
    _, j_long = long_.assign(POP, SCHED, rng2)
    assert j_long <= j_short * 1.01
