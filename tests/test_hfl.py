"""HFL trainer (Algorithm 1, eqs. 2-3): aggregation math + learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hfl import (evaluate_in_batches, hfl_global_iteration,
                            pad_device_data)
from repro.data import make_dataset, partition_noniid
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _linear_apply(params, X):
    return X.reshape(X.shape[0], -1) @ params["w"]


def test_edge_and_cloud_aggregation_weights():
    """With L chosen so locals stay put (lr=0), the aggregate must be the
    D_n-weighted mean of identical models = the global model itself."""
    w0 = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 3)))}
    H, Dmax = 6, 5
    X = jnp.zeros((H, Dmax, 2, 2, 1))
    y = jnp.zeros((H, Dmax), jnp.int32)
    mask = jnp.ones((H, Dmax))
    sizes = jnp.asarray([1., 2., 3., 4., 5., 6.])
    assign = jnp.asarray([0, 0, 1, 1, 2, 2])
    out = hfl_global_iteration(_linear_apply, w0, X, y, mask, sizes, assign,
                               M=3, L=2, Q=2, lr=0.0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(w0["w"]),
                               atol=1e-6)


def test_single_device_single_edge_equals_local_sgd():
    """H=1, M=1: HFL reduces to plain local training (eq. 16 telescoping)."""
    from repro.core.local_train import local_sgd
    rng = np.random.default_rng(0)
    X1 = jnp.asarray(rng.normal(0, 1, (1, 8, 2, 2, 1)).astype(np.float32))
    y1 = jnp.asarray(rng.integers(0, 3, (1, 8)).astype(np.int32))
    m1 = jnp.ones((1, 8))
    w0 = {"w": jnp.asarray(rng.normal(0, 0.1, (4, 3)).astype(np.float32))}
    out = hfl_global_iteration(_linear_apply, w0, X1, y1, m1,
                               jnp.ones(1), jnp.zeros(1, jnp.int32),
                               M=1, L=3, Q=2, lr=0.05)
    # manual: Q rounds of (L local steps from the aggregated model)
    w = w0
    for _ in range(2):
        w = local_sgd(_linear_apply, w, X1[0], y1[0], m1[0], 3, 0.05)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(w["w"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_hfl_cnn_learns_synthetic():
    """A few global iterations must beat chance on the synthetic dataset."""
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=1500, n_test=400, seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=12, size_range=(40, 60),
                           seed=0)
    Xp, yp, mask = pad_device_data(fed)
    params = cnn.cnn_init(KEY, (28, 28), 1)
    sched = np.arange(12)
    assign = np.asarray(sched % 3)
    acc0 = evaluate_in_batches(cnn.cnn_apply, params, fed.X_test, fed.y_test)
    for _ in range(3):
        params = hfl_global_iteration(
            cnn.cnn_apply, params, Xp[sched], yp[sched], mask[sched],
            jnp.asarray(fed.sizes[sched], jnp.float32), jnp.asarray(assign),
            M=3, L=3, Q=2, lr=0.02)   # lr=0.05 diverges on this tiny split
    acc1 = evaluate_in_batches(cnn.cnn_apply, params, fed.X_test, fed.y_test)
    assert acc1 > max(acc0, 0.15)


def test_empty_edge_keeps_model_valid():
    w0 = {"w": jnp.ones((4, 3))}
    H, Dmax = 2, 4
    X = jnp.zeros((H, Dmax, 2, 2, 1))
    y = jnp.zeros((H, Dmax), jnp.int32)
    mask = jnp.ones((H, Dmax))
    out = hfl_global_iteration(_linear_apply, w0, X, y, mask,
                               jnp.ones(H), jnp.zeros(H, jnp.int32),
                               M=3, L=1, Q=1, lr=0.0)   # edges 1,2 empty
    assert bool(jnp.all(jnp.isfinite(out["w"])))
