"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device (only launch/dryrun.py forces 512 placeholders)."""
import os

# determinism + quieter logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
