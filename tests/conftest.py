"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device (only launch/dryrun.py forces 512 placeholders)."""
import os

# determinism + quieter logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def small_world():
    """Cached (SystemParams, Population, FederatedData) — built ONCE per
    session. The synthetic dataset + non-IID partition cost seconds per
    build and several integration modules need an identical world, so
    sharing it keeps tier-1 wall time down. Treat it as read-only."""
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_dataset, partition_noniid

    sp = SystemParams(n_devices=20, n_edges=3)
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=1200, n_test=300,
                                seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=20, size_range=(30, 50),
                           seed=0)
    return sp, pop, fed
