"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device (only launch/dryrun.py forces 512 placeholders,
and the ``multidevice`` fixture spawns subprocesses that force 8)."""
import os
import subprocess
import sys

# determinism + quieter logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
_REPO_ROOT = os.path.dirname(_TESTS_DIR)


@pytest.fixture
def multidevice():
    """Run a zero-arg payload function in a subprocess with N emulated
    host devices. ``XLA_FLAGS=--xla_force_host_platform_device_count``
    only takes effect before jax import, and this conftest (plus half
    the suite) has already imported jax — so multidevice tests put the
    device-dependent asserts in a module-level ``_payload_*`` function
    and hand ``"module:function"`` to this fixture, which spawns a fresh
    interpreter with the flag set and fails the test with the child's
    output on a non-zero exit.
    """

    def run(target: str, n_devices: int = 8, timeout: int = 1200):
        from repro.utils import forced_device_env

        mod, fn = target.split(":")
        env = forced_device_env(
            n_devices,
            pythonpath=(os.path.join(_REPO_ROOT, "src"), _TESTS_DIR))
        proc = subprocess.run(
            [sys.executable, "-c",
             f"import {mod} as _m; _m.{fn}()"],
            env=env, cwd=_REPO_ROOT, capture_output=True, text=True,
            timeout=timeout)
        if proc.returncode != 0:
            pytest.fail(
                f"multidevice payload {target} failed (exit "
                f"{proc.returncode}):\n{proc.stdout}\n{proc.stderr}",
                pytrace=False)
        return proc.stdout

    return run


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def small_world():
    """Cached (SystemParams, Population, FederatedData) — built ONCE per
    session. The synthetic dataset + non-IID partition cost seconds per
    build and several integration modules need an identical world, so
    sharing it keeps tier-1 wall time down. Treat it as read-only."""
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_dataset, partition_noniid

    sp = SystemParams(n_devices=20, n_edges=3)
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=1200, n_test=300,
                                seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=20, size_range=(30, 50),
                           seed=0)
    return sp, pop, fed
