"""Fused whole-sweep engine: single-dispatch scan vs the per-round paths.

Parity matrix (who is the oracle for what):

* traced assigners (geo/drl) — compared element-for-element against
  their host ``Assigner`` twins on random worlds (deterministic, exact
  up to f32-vs-f64 distance/Q ties, which the worlds below don't hit).
* ``run(fused=True)`` with geo/drl — compared against the legacy
  per-round host loop ``run(fused=False)``: scheduling is precomputed
  from the same numpy rng stream and both assigners are deterministic,
  so schedules/assignments/costs agree exactly and accuracy agrees up
  to XLA fusion drift (same tolerances as ``tests/test_sweep_shard``).
* ``run(fused=True)`` with hfel — compared against ``fused="oracle"``
  (the SAME traced step driven one dispatch per round): the in-scan JAX
  proposal stream has no host twin, so the oracle is the exact
  reference; traced-search *quality* vs the host batched search is
  asserted statistically instead.
* sharded fused (non-divisible S, dead pad lanes) — multidevice
  subprocess payload, fused-sharded vs fused-single-device.
"""
import numpy as np
import pytest

_N, _M, _H = 12, 3, 8
_ROUNDS = 3


def _make_world():
    from repro.core.cost_model import SystemParams, sample_population
    from repro.data import make_dataset, partition_noniid

    sp = SystemParams(n_devices=_N, n_edges=_M)
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=240, n_test=60,
                                seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=_N,
                           size_range=(10, 16), seed=0)
    return sp, pop, fed


def _make_runner(S, shard=False):
    from repro.core.sweep import SweepRunner

    sp, pop, fed = _make_world()
    return SweepRunner(sp, [(pop, fed)] * S, lr=0.02, alloc_steps=25,
                       model_seed=0, shard=shard), sp, fed


def _scheds(sp, fed, S):
    from repro.core.sweep import build_scheduler

    return [build_scheduler("fedavg", fed, sp, _H, seed=s)
            for s in range(S)]


def _drl_params():
    import jax

    from repro.drl.d3qn import d3qn_init

    return d3qn_init(jax.random.PRNGKey(0), _M + 3, _M)


def _assert_parity(o0, o1, acc_atol=0.09):
    """Same contract as tests/test_sweep_shard._assert_parity: costs are
    functions of (sched, assign, done) only and must agree tightly;
    accuracy rides trained params where ~ulp XLA drift compounds."""
    assert o0["acc"].shape == o1["acc"].shape
    np.testing.assert_array_equal(o0["iters"], o1["iters"])
    for k in ("T_i", "E_i", "obj"):
        np.testing.assert_allclose(o0[k], o1[k], rtol=1e-4, atol=1e-6,
                                   err_msg=k)
    np.testing.assert_allclose(o0["acc"], o1["acc"], atol=acc_atol)
    assert o0["H"] == o1["H"]


# ------------------------------------------------------- traced assigners

def test_traced_geo_matches_host():
    """geo_assign_traced == GeoAssigner.assign on random worlds."""
    import jax.numpy as jnp

    from repro.core.assignment.geo import GeoAssigner, geo_assign_traced
    from repro.core.cost_model import SystemParams, sample_population

    rng = np.random.default_rng(0)
    for seed in range(5):
        sp = SystemParams(n_devices=_N, n_edges=_M)
        pop = sample_population(sp, seed=seed)
        sched = rng.permutation(_N)[:_H]
        host, _ = GeoAssigner(None).assign(pop, sched, rng)
        traced = geo_assign_traced(jnp.asarray(pop.dev_pos),
                                   jnp.asarray(pop.edge_pos),
                                   jnp.asarray(sched))
        np.testing.assert_array_equal(np.asarray(traced), np.asarray(host))


def test_traced_drl_matches_host():
    """drl_assign_traced == DRLAssigner.assign (greedy argmax-Q)."""
    import jax.numpy as jnp

    from repro.core.assignment.drl import DRLAssigner, drl_assign_traced
    from repro.core.cost_model import SystemParams, sample_population

    params = _drl_params()
    rng = np.random.default_rng(1)
    for seed in range(3):
        sp = SystemParams(n_devices=_N, n_edges=_M)
        pop = sample_population(sp, seed=seed)
        sched = rng.permutation(_N)[:_H]
        host, _ = DRLAssigner(sp, params).assign(pop, sched)
        traced = drl_assign_traced(
            params, jnp.asarray(pop.u), jnp.asarray(pop.D),
            jnp.asarray(pop.p), jnp.asarray(pop.g), jnp.asarray(sched))
        np.testing.assert_array_equal(np.asarray(traced), np.asarray(host))


def test_traced_fedavg_scheduler():
    """TracedFedAvg: H-sized duplicate-free draws from [0, N), a fresh
    cohort per step, and threaded key state (same seed -> same stream)."""
    from repro.core.scheduling.schedulers import TracedFedAvg

    ts = TracedFedAvg(_N, _H)
    st = ts.init_state(0)
    draws = []
    for _ in range(3):
        st, sched = ts.step(st)
        s = np.asarray(sched)
        assert s.shape == (_H,)
        assert len(set(s.tolist())) == _H
        assert s.min() >= 0 and s.max() < _N
        draws.append(s)
    assert any(not np.array_equal(draws[0], d) for d in draws[1:])
    # determinism: replaying from the same seed reproduces the stream
    st2 = ts.init_state(0)
    st2, again = ts.step(st2)
    np.testing.assert_array_equal(np.asarray(again), draws[0])
    with pytest.raises(ValueError):
        TracedFedAvg(_N, 0)
    with pytest.raises(ValueError):
        TracedFedAvg(_N, _N + 1)


# ------------------------------------------------------ runner validation

def test_fused_rejects_bad_configs():
    from repro.core.scheduling.schedulers import TracedFedAvg

    runner, sp, fed = _make_runner(2)
    scheds = _scheds(sp, fed, 2)
    with pytest.raises(ValueError, match="fused must be"):
        runner.run(scheds, 1, fused="yes")
    with pytest.raises(ValueError, match="named assigner"):
        runner.run(scheds, 1, assign=lambda *a: None, fused=True)
    with pytest.raises(ValueError, match="unknown assign"):
        runner.run(scheds, 1, assign="nope", fused=True)
    with pytest.raises(ValueError, match="drl_params"):
        runner.run(scheds, 1, assign="drl", fused=True)
    with pytest.raises(ValueError, match="hfel_opts"):
        runner.run(scheds, 1, assign="geo", fused=True,
                   hfel_opts={"n_transfer": 4})
    with pytest.raises(ValueError, match="unknown hfel_opts"):
        runner.run(scheds, 1, assign="hfel", fused=True,
                   hfel_opts={"alloc_steps": 5})
    with pytest.raises(ValueError, match="cannot mix"):
        runner.run([scheds[0], TracedFedAvg(_N, _H)], 1, fused=True)
    with pytest.raises(ValueError, match="share one"):
        runner.run([TracedFedAvg(_N, _H), TracedFedAvg(_N, _H - 1)], 1,
                   fused=True)


# -------------------------------------------------------- fused parity

def test_fused_geo_single_dispatch_parity():
    """Tier-1 fused smoke: an S=3, R=3 geo sweep through ONE dispatch
    matches the per-round host loop, including per-lane early stop.

    The early-stop target comes from a no-stop probe (pre-stop
    trajectories are engine-independent), picked mid-gap so tolerated
    accuracy drift cannot flip a stopping round."""
    runner, sp, fed = _make_runner(3)
    probe = runner.run(_scheds(sp, fed, 3), _ROUNDS, assign="geo")
    fused = runner.run(_scheds(sp, fed, 3), _ROUNDS, assign="geo",
                       fused=True)
    assert fused["n_dispatches"] == 1
    _assert_parity(probe, fused)

    accs = probe["acc"]
    vals = np.unique(accs)
    best, best_margin = None, 0.0
    for t in (vals[:-1] + vals[1:]) / 2:
        reached = accs >= t
        iters = np.where(reached.any(axis=1),
                         reached.argmax(axis=1) + 1, _ROUNDS)
        if iters.min() < _ROUNDS and len(set(iters.tolist())) > 1:
            margin = float(np.abs(accs - t).min())
            if margin > best_margin:
                best, best_margin = float(t), margin
    if best is None:
        pytest.skip(f"no divergent early-stop target in {accs}")
    o_host = runner.run(_scheds(sp, fed, 3), _ROUNDS, assign="geo",
                        target_acc=best)
    o_fused = runner.run(_scheds(sp, fed, 3), _ROUNDS, assign="geo",
                         target_acc=best, fused=True)
    assert o_fused["n_dispatches"] == 1
    _assert_parity(o_host, o_fused, acc_atol=min(0.09, best_margin))


@pytest.mark.slow
def test_fused_drl_parity():
    """Greedy D3QN deployment in-scan vs the host per-round loop."""
    runner, sp, fed = _make_runner(2)
    params = _drl_params()
    host = runner.run(_scheds(sp, fed, 2), 2, assign="drl",
                      drl_params=params)
    fused = runner.run(_scheds(sp, fed, 2), 2, assign="drl",
                       drl_params=params, fused=True)
    assert fused["n_dispatches"] == 1
    _assert_parity(host, fused, acc_atol=0.15)


@pytest.mark.slow
def test_fused_hfel_matches_oracle():
    """In-scan hfel has no host rng twin: the exact reference is the
    SAME traced step driven per-round (fused='oracle')."""
    runner, sp, fed = _make_runner(2)
    opts = dict(n_transfer=8, n_exchange=8, n_candidates=8)
    fused = runner.run(_scheds(sp, fed, 2), 2, assign="hfel", fused=True,
                      hfel_opts=opts)
    orac = runner.run(_scheds(sp, fed, 2), 2, assign="hfel",
                      fused="oracle", hfel_opts=opts)
    assert fused["n_dispatches"] == 1
    assert orac["n_dispatches"] == 2
    _assert_parity(orac, fused, acc_atol=0.09)


@pytest.mark.slow
def test_fused_traced_scheduler_matches_oracle():
    """In-scan TracedFedAvg scheduling: carried key state threads
    identically through one R-round scan and R single-round dispatches."""
    from repro.core.scheduling.schedulers import TracedFedAvg

    runner, sp, fed = _make_runner(2)
    ts = [TracedFedAvg(_N, _H) for _ in range(2)]
    fused = runner.run(ts, 2, assign="geo", fused=True)
    orac = runner.run(ts, 2, assign="geo", fused="oracle")
    assert fused["H"] == _H
    _assert_parity(orac, fused)


@pytest.mark.slow
def test_traced_hfel_search_quality():
    """The traced K-candidate search draws proposals from a JAX stream
    (no bitwise host parity possible); assert it IMPROVES on the
    max-gain warm start and lands within 15% of the host batched
    search's objective under the same trial budgets."""
    import jax
    import jax.numpy as jnp

    from repro.core.assignment.hfel import (HFELAssigner, _objective,
                                            hfel_search_traced)
    from repro.core import cost_model as cm
    from repro.core import resource as ra
    from repro.core.cost_model import SystemParams, sample_population

    sp = SystemParams(n_devices=_N, n_edges=_M)
    pop = sample_population(sp, seed=0)
    sched = np.arange(_H)
    kw = dict(n_transfer=24, n_exchange=24, n_candidates=8)
    host = HFELAssigner(sp, alloc_steps=60, search="batched", **kw)
    a_host, J_host = host.assign(pop, sched, np.random.default_rng(0))

    u, D, p = pop.u[sched], pop.D[sched], pop.p[sched]
    g = pop.g[sched]
    a_tr, J_tr = hfel_search_traced(
        sp, jnp.asarray(u), jnp.asarray(D), jnp.asarray(p),
        jnp.asarray(g), jnp.asarray(pop.B_m), jnp.asarray(pop.g_cloud),
        jax.random.PRNGKey(0), alloc_steps=60, warm_steps=None, **kw,
        accept_top=4)
    a_tr = np.asarray(a_tr)
    assert a_tr.shape == (_H,)
    assert a_tr.min() >= 0 and a_tr.max() < _M

    # cold objective of the warm-start assignment (best-gain edge)
    T_cl, E_cl = cm.cloud_cost(sp, pop.g_cloud)
    a0 = pop.g[sched].argmax(axis=1)
    mask0 = a0[None, :] == np.arange(_M)[:, None]
    res0, _ = ra.allocate_batch_warm(
        sp, jnp.broadcast_to(jnp.asarray(u), (_M, _H)),
        jnp.broadcast_to(jnp.asarray(D), (_M, _H)),
        jnp.broadcast_to(jnp.asarray(p), (_M, _H)),
        jnp.asarray(g.T), jnp.asarray(pop.B_m), jnp.asarray(mask0),
        jnp.zeros((_M, _H), jnp.float32), jnp.ones((_M, _H), jnp.float32),
        steps=60)
    J0 = float(np.asarray(_objective(
        jnp.asarray(res0.T_edge), jnp.asarray(res0.E_edge),
        jnp.asarray(T_cl, jnp.float32), jnp.asarray(E_cl, jnp.float32),
        sp.lam)))
    assert float(J_tr) <= J0 + 1e-6, (float(J_tr), J0)
    assert float(J_tr) <= 1.15 * float(J_host), (float(J_tr), float(J_host))


# ------------------------------------------------- multidevice payloads

def _payload_fused_shard():
    """Fused scan under shard_map: S=5 lanes on 8 emulated devices
    (non-divisible — 3 dead pad lanes inside the scan carry), with
    early stop, vs the fused single-device run. Both sides are ONE
    dispatch; the shard side's is an SPMD program."""
    import jax

    assert len(jax.devices()) == 8, jax.devices()
    r0, sp, fed = _make_runner(5, shard=False)
    r1, _, _ = _make_runner(5, shard=True)
    assert r1.S_pad == 8
    kw = dict(n_rounds=_ROUNDS, assign="geo", target_acc=0.30, fused=True)
    o0 = r0.run(_scheds(sp, fed, 5), **kw)
    o1 = r1.run(_scheds(sp, fed, 5), **kw)
    assert o0["n_dispatches"] == o1["n_dispatches"] == 1
    _assert_parity(o0, o1)


@pytest.mark.multidevice
@pytest.mark.slow
def test_fused_sharded_parity_nondivisible(multidevice):
    multidevice("test_sweep_fused:_payload_fused_shard")
