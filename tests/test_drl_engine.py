"""Batched D3QN episode engine: parity against the serial Alg. 5 oracle.

Three deterministic pins:

* imitation targets — ``HFELAssigner.assign_batch``'s lockstep waves
  visit the same proposals/solves/accepts as E independent ``assign``
  calls, so same populations + same search rngs => SAME targets;
* the jitted ``lax.scan`` update wave == the serial update loop (incl.
  the every-J target sync) on an identical minibatch stream => same
  params => same greedy actions after equal updates;
* deployment — ``DRLAssigner.assign_batch`` row e == per-population
  ``assign``, and ``SweepRunner.run(assign="drl")`` runs end-to-end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.assignment.drl import DRLAssigner
from repro.core.assignment.hfel import HFELAssigner
from repro.drl.d3qn import d3qn_init, q_values_all_t
from repro.drl.train import (D3QNTrainer, drl_features, drl_features_batch,
                             make_training_population)

SP = cm.SystemParams(n_devices=10, n_edges=3)
SCHED = np.arange(10)


def _pop_batch(n=3, seeds=(11, 22, 33)):
    return cm.sample_population_batch(SP, seeds=list(seeds[:n]))


def test_population_batch_matches_per_seed_sampling():
    """Population e of a batch is the SAME world sample_population(seed_e)
    yields — the guarantee both trainer engines rely on."""
    popb = _pop_batch()
    for e, seed in enumerate((11, 22, 33)):
        pop = cm.sample_population(SP, seed=seed)
        for name in ("u", "D", "p", "g", "g_cloud", "B_m"):
            np.testing.assert_array_equal(
                np.asarray(getattr(popb, name)[e]),
                np.asarray(getattr(pop, name)), err_msg=name)


def test_drl_features_batch_matches_serial():
    popb = _pop_batch()
    batched = drl_features_batch(popb)
    for e in range(popb.n_pops):
        np.testing.assert_allclose(batched[e], drl_features(popb.pop(e)),
                                   rtol=1e-12)
    sub = drl_features_batch(popb, SCHED[:6])
    np.testing.assert_allclose(
        sub[1], drl_features(popb.pop(1), SCHED[:6]), rtol=1e-12)


def test_hfel_assign_batch_matches_per_population_assign():
    """Same populations + same per-population search rngs => the lockstep
    waves reproduce E independent batched searches exactly."""
    popb = _pop_batch()
    hfel = HFELAssigner(SP, n_transfer=12, n_exchange=16, alloc_steps=50,
                        n_candidates=4)
    A, J = hfel.assign_batch(popb, SCHED,
                             [np.random.default_rng(s) for s in (0, 1, 2)])
    assert A.shape == (3, 10) and J.shape == (3,)
    for e in range(3):
        a, j = hfel.assign(popb.pop(e), SCHED, np.random.default_rng(e))
        np.testing.assert_array_equal(A[e], a)
        assert J[e] == pytest.approx(j, rel=1e-6)


def test_hfel_assign_batch_serial_fallback_and_validation():
    popb = _pop_batch(2)
    ser = HFELAssigner(SP, n_transfer=6, n_exchange=8, alloc_steps=40,
                       search="serial")
    A, J = ser.assign_batch(popb, SCHED, [0, 1])
    for e in range(2):
        a, j = ser.assign(popb.pop(e), SCHED, np.random.default_rng(e))
        np.testing.assert_array_equal(A[e], a)
        assert J[e] == pytest.approx(j, rel=1e-9)
    bad = HFELAssigner(SP, search="magic")
    with pytest.raises(ValueError, match="search engine"):
        bad.assign_batch(popb, SCHED, [0, 1])


def test_update_wave_matches_serial_update_loop():
    """The jitted scan == the serial per-episode update loop (same
    minibatch stream, same every-J target sync) => identical params and
    identical greedy actions after equal updates."""
    tr = D3QNTrainer(SP, H=8, hidden=16, minibatch=16, target_sync=2,
                     seed=3)
    rng = np.random.default_rng(0)
    for _ in range(6):       # fill the replay ring with fake episodes
        feats = rng.random((8, tr.feat_dim)).astype(np.float32)
        acts = rng.integers(0, SP.n_edges, 8)
        tr.replay.push(feats, acts, np.where(acts == 0, 1.0, -1.0))
    U = 5
    mbs = tr.replay.sample_updates(np.random.default_rng(7), U,
                                   tr.minibatch)
    feats_u, ep_idx_u, slots_u, acts_u, rews_u = [
        jnp.asarray(a) for a in mbs]
    rews_u = rews_u.astype(jnp.float32)

    # serial oracle: U x (_update + host-side target sync)
    params, opt_state = tr.params, tr.opt_state
    target = tr.target_params
    for u in range(U):
        params, opt_state, _ = tr._update(
            params, opt_state, target, feats_u[u], ep_idx_u[u],
            slots_u[u], acts_u[u], rews_u[u])
        if (u + 1) % tr.target_sync == 0:
            target = jax.tree.map(jnp.copy, params)

    (p_w, _, t_w, step), losses = tr._update_wave(
        tr.params, tr.opt_state, tr.target_params,
        jnp.asarray(0, jnp.int32), feats_u, ep_idx_u, slots_u, acts_u,
        rews_u)
    assert int(step) == U and losses.shape == (U,)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), params, p_w)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), target, t_w)
    probe = jnp.asarray(rng.random((8, tr.feat_dim)), jnp.float32)
    a_ser = np.asarray(q_values_all_t(params, probe)).argmax(-1)
    a_bat = np.asarray(q_values_all_t(p_w, probe)).argmax(-1)
    np.testing.assert_array_equal(a_ser, a_bat)


def test_trainer_batched_wave_targets_match_serial_oracle():
    """run_wave trains on the serial oracle's per-episode populations:
    its HFEL targets equal per-population searches at the wave's seeds,
    and the +-1 rewards (eq. 26) follow from them."""
    tr = D3QNTrainer(SP, H=8, hidden=16, hfel_transfer=6, hfel_exchange=8,
                     alloc_steps=40, minibatch=1000, wave_size=2, seed=5)
    rng_probe = np.random.default_rng(5)   # same stream the trainer uses
    pop_seeds = [int(rng_probe.integers(1 << 31)) for _ in range(2)]
    rets, _ = tr.run_wave()
    assert rets.shape == (2,) and tr.episode == 2
    assert tr.replay.n_episodes == 2
    for e, s in enumerate(pop_seeds):
        pop = make_training_population(SP, 8, seed=s)
        a, _ = tr.hfel.assign(pop, np.arange(8),
                              np.random.default_rng(s ^ 0x5EED))
        # reward +1 where the wave's action hit this target, else -1
        rew = np.asarray(tr.replay._rewards[e])
        act = np.asarray(tr.replay._actions[e])
        np.testing.assert_array_equal(rew, np.where(act == a, 1.0, -1.0))


def test_trainer_unknown_engine_raises():
    with pytest.raises(ValueError, match="training engine"):
        D3QNTrainer(SP, H=8, engine="warp")


def test_drl_assigner_batch_matches_per_population():
    params = d3qn_init(jax.random.PRNGKey(0), SP.n_edges + 3, SP.n_edges,
                       hidden=16)
    assigner = DRLAssigner(SP, params)
    popb = _pop_batch()
    A, _ = assigner.assign_batch(popb, SCHED)
    assert A.shape == (3, 10)
    for e in range(3):
        a, _ = assigner.assign(popb.pop(e), SCHED)
        np.testing.assert_array_equal(A[e], a)
    # sequence-of-populations input hits the same path
    A2, _ = assigner.assign_batch(popb.populations(), SCHED)
    np.testing.assert_array_equal(A, A2)


@pytest.mark.slow
def test_sweep_runner_drl_assign_end_to_end(small_world):
    """SweepRunner.run(assign="drl") drives a full vmapped sweep with a
    (here untrained) D3QN agent: valid edges, finite costs."""
    sp, pop, fed = small_world
    from repro.core.scheduling import FedAvgScheduler
    from repro.core.sweep import SweepRunner
    params = d3qn_init(jax.random.PRNGKey(1), sp.n_edges + 3, sp.n_edges,
                       hidden=16)
    runner = SweepRunner(sp, [(pop, fed), (pop, fed)], lr=0.01,
                         alloc_steps=50, model_seed=0)
    scheds = [FedAvgScheduler(fed.n_devices, 8) for _ in range(2)]
    out = runner.run(scheds, n_rounds=2, assign="drl", seeds=[0, 1],
                     drl_params=params)
    assert out["acc"].shape == (2, 2)
    assert np.isfinite(out["T_i"]).all() and (out["T_i"] > 0).all()
    with pytest.raises(ValueError, match="drl_params"):
        runner.run(scheds, 1, assign="drl")


def test_trainer_batched_engine_learns_reward_signal():
    """Sanity: a few batched waves run end-to-end and produce updates
    (step advances, losses finite) at tiny shapes."""
    sp = dataclasses.replace(SP, n_edges=3)
    tr = D3QNTrainer(sp, H=8, hidden=16, hfel_transfer=4, hfel_exchange=6,
                     alloc_steps=30, minibatch=16, wave_size=3, seed=0)
    hist = tr.train(max_episodes=6, verbose=False)
    assert len(hist) == 6 and tr.episode == 6
    assert tr.step > 0                      # buffer warmed, scan updates ran
    assert all(-8.0 <= r <= 8.0 for r in hist)
