"""Cost model (eqs. 4-14): units, monotonicity, structure — incl. hypothesis
property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cost_model as cm

SP = cm.SystemParams()
POP = cm.sample_population(SP, seed=3)


def test_population_shapes_and_ranges():
    assert POP.g.shape == (SP.n_devices, SP.n_edges)
    assert float(POP.u.min()) >= SP.u_range[0]
    assert float(POP.u.max()) <= SP.u_range[1]
    assert float(POP.D.min()) >= SP.d_range[0]
    assert float(POP.D.max()) <= SP.d_range[1]
    assert np.all(np.asarray(POP.B_m) >= SP.edge_bw_range[0])
    assert np.all(np.asarray(POP.g) > 0)


def test_population_batch_shapes_and_seed_derivation():
    """sample_population_batch: stacked (E, ...) arrays; one `seed` derives
    a deterministic population set; `pop(e)` round-trips to Population."""
    popb = cm.sample_population_batch(SP, n_pops=3, seed=7)
    E, N, M = 3, SP.n_devices, SP.n_edges
    assert popb.n_pops == E and popb.n_devices == N and popb.n_edges == M
    assert popb.g.shape == (E, N, M) and popb.B_m.shape == (E, M)
    assert popb.features().shape == (E, N, M + 3)
    popb2 = cm.sample_population_batch(SP, n_pops=3, seed=7)
    np.testing.assert_array_equal(np.asarray(popb.g), np.asarray(popb2.g))
    pop1 = popb.pop(1)
    assert isinstance(pop1, cm.Population)
    np.testing.assert_array_equal(np.asarray(pop1.features()),
                                  np.asarray(popb.features()[1]))
    with pytest.raises(ValueError, match="n_pops or seeds"):
        cm.sample_population_batch(SP)


@given(f=st.floats(1e8, 2e9), u=st.floats(1e4, 1e5), D=st.floats(300, 700))
@settings(max_examples=50, deadline=None)
def test_cmp_scaling_properties(f, u, D):
    """(4)/(5): T ~ 1/f, E ~ f^2; both linear in u*D."""
    t1 = float(cm.t_cmp(SP, u, D, f))
    t2 = float(cm.t_cmp(SP, u, D, 2 * f))
    assert t1 == pytest.approx(2 * t2, rel=1e-6)
    e1 = float(cm.e_cmp(SP, u, D, f))
    e2 = float(cm.e_cmp(SP, u, D, 2 * f))
    assert e2 == pytest.approx(4 * e1, rel=1e-6)
    assert float(cm.t_cmp(SP, 2 * u, D, f)) == pytest.approx(2 * t1, rel=1e-6)


@given(b=st.floats(1e4, 3e6), g=st.floats(1e-14, 1e-8),
       p=st.floats(1e-4, 0.2))
@settings(max_examples=50, deadline=None)
def test_rate_monotone_in_bandwidth_and_power(b, g, p):
    r1 = float(cm.uplink_rate(SP, b, g, p))
    r2 = float(cm.uplink_rate(SP, 2 * b, g, p))
    r3 = float(cm.uplink_rate(SP, b, g, 2 * p))
    # more bandwidth -> more rate; in the power-limited regime
    # (snr -> 0) the curve is asymptotically FLAT in b and f32 log1p's
    # relative error is ~eps/snr (~1e-3 at snr=1e-4), so allow 1% slack
    # (hypothesis keeps finding deeper power-limited corners)
    assert r2 >= r1 * 0.99 and r1 > 0
    assert r3 >= r1 * (1 - 1e-6)       # more power -> more rate
    # bandwidth has diminishing returns: rate sublinear in b
    assert r2 < 2 * r1 + 1e-6


def test_transmission_energy_consistency():
    """(8) == p * (7)."""
    b, g, p = 1e6, 1e-10, 0.1
    t = float(cm.t_com(SP, b, g, p))
    e = float(cm.e_com(SP, b, g, p))
    assert e == pytest.approx(p * t, rel=1e-6)


def test_round_cost_structure():
    H = 20
    sched = jnp.arange(H)
    assign = jnp.arange(H) % SP.n_edges
    b = jnp.full((H,), 2e5)
    f = jnp.full((H,), 1e9)
    T_i, E_i, T_m, E_m = cm.round_cost(SP, POP, sched, assign, b, f)
    assert T_m.shape == (SP.n_edges,)
    # (13): T_i is the max across edges; (14): E_i the sum
    assert float(T_i) == pytest.approx(float(jnp.max(T_m)), rel=1e-6)
    assert float(E_i) == pytest.approx(float(jnp.sum(E_m)), rel=1e-6)
    assert float(T_i) > 0 and float(E_i) > 0


def test_straggler_dominates_edge_delay():
    """(9): edge delay is Q * max over its devices."""
    u = jnp.array([1e4, 1e5])
    D = jnp.array([400.0, 700.0])
    p = jnp.array([0.1, 0.1])
    g = jnp.array([1e-10, 1e-10])
    b = jnp.array([1e6, 1e6])
    f = jnp.array([2e9, 2e9])
    mask = jnp.array([True, True])
    T_edge, E_edge = cm.edge_round_cost(SP, u, D, p, g, b, f, mask)
    t_each = cm.t_cmp(SP, u, D, f) + cm.t_com(SP, b, g, p)
    assert float(T_edge) == pytest.approx(SP.Q * float(t_each.max()), rel=1e-6)


def test_cloud_cost_constant_in_devices():
    T1, E1 = cm.cloud_cost(SP, POP.g_cloud[0])
    assert float(T1) > 0 and float(E1) > 0


def test_channel_gain_decreases_with_distance():
    rng = np.random.default_rng(0)
    g_near = cm._gain(rng, np.array([0.05]), 0.0)
    g_far = cm._gain(rng, np.array([0.9]), 0.0)
    assert g_near[0] > g_far[0]
