"""Distributed-step integration on the 1-device debug mesh: the lowered
train_step must actually learn, and serve_step must be self-consistent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import token_batch_iterator
from repro.launch import steps as S
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T


@pytest.mark.slow
def test_train_step_decreases_loss():
    mesh = make_debug_mesh()
    cfg = dataclasses.replace(get_smoke_config("chatglm3-6b"),
                              microbatches=2)
    with mesh:
        step, opt = S.make_train_step(cfg, mesh, lr=3e-3)
        params = T.init(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
        it = token_batch_iterator(cfg.vocab_size, batch=8, seq=32, seed=0)
        step_j = jax.jit(step)
        losses = []
        for i in range(30):
            b = next(it)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt_state, m = step_j(params, opt_state, batch)
            losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


@pytest.mark.slow
def test_serve_step_matches_prefill():
    mesh = make_debug_mesh()
    cfg = get_smoke_config("mistral-nemo-12b")
    with mesh:
        serve = jax.jit(S.make_serve_step(cfg, mesh))
        params = T.init(jax.random.PRNGKey(0), cfg)
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                 cfg.vocab_size)
        full, _ = T.forward(params, {"tokens": tok}, cfg)
        cache = T.init_cache(cfg, 2, max_len=10)
        for t in range(10):
            logits, cache = serve(params, cache, tok[:, t:t + 1],
                                  jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, -1]), atol=5e-4)


def test_input_specs_cover_all_shapes():
    from repro.configs.base import INPUT_SHAPES
    mesh = make_debug_mesh()
    for arch in ("internvl2-26b", "musicgen-medium", "llama3-405b"):
        from repro.configs.registry import get_config, variant_for_shape
        for shp in INPUT_SHAPES.values():
            cfg = variant_for_shape(get_config(arch), shp)
            specs = S.input_specs(cfg, shp, mesh)
            assert "tokens" in specs
            if shp.kind in ("train", "prefill"):
                tot = specs["tokens"].shape[1] + (cfg.n_prefix_embeds or 0)
                assert tot == shp.seq_len
            else:
                assert specs["tokens"].shape[1] == 1
