"""Launch-layer unit tests that need no device mesh beyond 1 CPU."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import (ARCH_IDS, get_config, get_smoke_config,
                                    variant_for_shape)
from repro.launch import steps as S
from repro.launch.mesh import make_debug_mesh


def test_variant_for_shape_swa_rules():
    long = INPUT_SHAPES["long_500k"]
    for arch in ARCH_IDS:
        cfg = variant_for_shape(get_config(arch), long)
        if cfg.family == "ssm":
            assert cfg.sliding_window == 0      # constant-state, no SWA
        else:
            assert cfg.sliding_window == 8192   # bounded KV state
    # other shapes never get SWA injected
    for sn in ("train_4k", "prefill_32k", "decode_32k"):
        cfg = variant_for_shape(get_config("llama3-405b"), INPUT_SHAPES[sn])
        assert cfg.sliding_window == 0


def test_decode_cache_is_bounded_for_long_500k():
    from repro.models import transformer as T
    long = INPUT_SHAPES["long_500k"]
    cfg = variant_for_shape(get_smoke_config("mistral-large-123b"), long)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 1, long.seq_len))
    kv_slots = cache[0]["k"].shape[2]
    assert kv_slots == 8192                     # rolling SWA cache
    cfg_ssm = variant_for_shape(get_smoke_config("mamba2-2.7b"), long)
    cache = jax.eval_shape(lambda: T.init_cache(cfg_ssm, 1, long.seq_len))
    assert cache[0]["ssm"].shape[-1] == cfg_ssm.ssm.d_state  # O(1) state


def test_input_specs_audio_and_vlm():
    mesh = make_debug_mesh()
    aud = get_config("musicgen-medium")
    sp = S.input_specs(aud, INPUT_SHAPES["train_4k"], mesh)
    assert sp["tokens"].shape == (256, 4096, 4)
    vlm = get_config("internvl2-26b")
    sp = S.input_specs(vlm, INPUT_SHAPES["train_4k"], mesh)
    assert sp["prefix_embeds"].shape == (256, 256, 6144)
    assert sp["tokens"].shape == (256, 4096 - 256)
    dec = S.input_specs(aud, INPUT_SHAPES["decode_32k"], mesh)
    assert dec["tokens"].shape == (128, 1, 4)


def test_optimizer_selection_by_size():
    from repro.optim.optimizers import Optimizer
    big = S.make_optimizer(get_config("llama3-405b"))
    small = S.make_optimizer(get_config("chatglm3-6b"))
    assert isinstance(big, Optimizer) and isinstance(small, Optimizer)
    p = {"w": jnp.zeros((8, 4))}
    sb = big.init(p)
    ss = small.init(p)
    assert "mom" in sb          # adafactor (factored)
    assert "m" in ss and "v" in ss  # adam


def test_drl_features_db_scale():
    from repro.core.cost_model import SystemParams, sample_population
    from repro.drl.train import drl_features
    sp = SystemParams(n_devices=12, n_edges=3)
    pop = sample_population(sp, seed=0)
    f = drl_features(pop)
    assert f.shape == (12, 6)
    assert np.isfinite(f).all()
    assert f.min() >= 0.0 and f.max() <= 1.0
    # dB scaling must spread the gain columns (raw min-max collapses them)
    spread = np.median(np.sort(f[:, 0])[1:-1])
    assert 0.02 < spread < 0.98


def test_parse_collectives():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ag = bf16[2,16,128]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add
  %nothing = f32[4]{0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 2 * 16 * 128 * 2
    assert out["all-reduce"]["bytes"] == 4096.0
    assert out["all-to-all"]["count"] == 0
