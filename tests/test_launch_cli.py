"""Launch entry points: the streaming serve CLI's checkpoint/eval
cadence (through the importable ``run_serve`` core) and the pure
HLO-parsing helpers of ``launch/dryrun.py`` (ISSUE 8 satellite —
previously untested entry points)."""
import json
import os

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.checkpoint import ckpt  # noqa: E402
from repro.launch.dryrun import _shape_bytes, parse_collectives  # noqa: E402
from repro.launch.serve import build_trace, run_serve  # noqa: E402

SMOKE = dict(n_devices=10, n_edges=3, H=6, n_train=300, n_test=120,
             alloc_steps=40, L=2, Q=3, seed=0)


def test_run_serve_checkpoint_and_eval_cadence(tmp_path):
    """4 streamed rounds, eval every 2, checkpoint every 2: JSON lines
    carry accuracy exactly on eval rounds; step dirs land on ckpt
    rounds; the summary counts both."""
    lines = []
    out = tmp_path / "summary.json"
    summary = run_serve(rounds=4, eval_every=2, ckpt_every=2,
                        ckpt_dir=str(tmp_path / "ck"),
                        out_json=str(out), log=lines.append, **SMOKE)

    recs = [json.loads(ln) for ln in lines]
    assert [r["round"] for r in recs] == [1, 2, 3, 4]
    assert [r["acc"] is not None for r in recs] == [False, True,
                                                   False, True]
    assert all(r["t"] > 0 for r in recs)

    assert summary["n_checkpoints"] == 2
    steps = sorted(os.listdir(tmp_path / "ck"))
    assert steps == ["step_00000002", "step_00000004"]
    assert ckpt.latest_step(str(tmp_path / "ck")) == 4

    saved = json.loads(out.read_text())
    assert saved["rounds"] == 4
    assert saved["final_acc"] == pytest.approx(recs[-1]["acc"])


def test_run_serve_restores_checkpointed_params(tmp_path):
    """The streamed checkpoints round-trip through restore_pytree."""
    from repro.core.async_engine import AsyncConfig, AsyncHFLEngine
    from repro.launch.serve import build_world
    run_serve(rounds=2, eval_every=0, ckpt_every=2,
              ckpt_dir=str(tmp_path), log=lambda _: None, **SMOKE)
    sp, pop, fed = build_world(10, 3, 300, 120, 0, L=2, Q=3)
    template = AsyncHFLEngine(sp, pop, fed, AsyncConfig(H=6)).model_params
    restored = ckpt.restore_pytree(template, str(tmp_path))
    import jax
    for leaf in jax.tree.leaves(restored):
        assert np.isfinite(np.asarray(leaf)).all()


def test_build_trace_presets():
    for name in ("always-on", "stationary", "diurnal", "bursty"):
        tr = build_trace(name, 8, seed=0)
        assert tr.n_devices == 8
        assert tr.latency_scale.shape == (8,)
    with pytest.raises(ValueError):
        build_trace("nope", 8, seed=0)
    assert build_trace("always-on", 8, seed=0).init_up.all()


# ------------------------------------------------------------- dryrun

def test_shape_bytes_parses_dtype_and_dims():
    assert _shape_bytes("bf16[16,512,1024]") == 16 * 512 * 1024 * 2
    assert _shape_bytes("f32[8,4]") == 8 * 4 * 4
    assert _shape_bytes("f32[]") == 4          # scalar
    assert _shape_bytes("not a shape") == 0


def test_parse_collectives_counts_ops_and_bytes():
    hlo = """
      ENTRY %main {
        %p0 = f32[8,4]{1,0} parameter(0)
        %ag = f32[16,4]{1,0} all-gather(%p0), replica_groups={{0,1}}
        %ar = f32[8,4]{1,0} all-reduce(%p0), to_apply=%add
        %mul = f32[8,4]{1,0} multiply(%p0, %p0)
      }
    """
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 16 * 4 * 4
    assert out["all-reduce"]["count"] == 1
    assert out["all-to-all"]["count"] == 0
