"""Vectorized schedulers vs the serial list-based oracles: per-round
cohort distribution equivalence, rotation invariants, empty-cluster
top-up, the SweepRunner K' < K short-cohort contract — and the Alg.-4
top-up rotation regression (a topped-up device must land in its
cluster's G_k, not stay re-pickable in C_k)."""
import numpy as np
import pytest

from repro.core.scheduling.schedulers import (
    FedAvgScheduler, IKCScheduler, SerialFedAvgScheduler,
    SerialIKCScheduler, SerialVKCScheduler, VKCScheduler)


def _freqs(sched_cls, args, rounds, seed, n):
    rng = np.random.default_rng(seed)
    s = sched_cls(*args)
    f = np.zeros(n, int)
    for _ in range(rounds):
        sel = s.schedule(rng)
        assert len(set(sel.tolist())) == len(sel)
        f[sel] += 1
    return f


@pytest.mark.parametrize("vec_cls,ser_cls,args_of", [
    (FedAvgScheduler, SerialFedAvgScheduler, lambda c: (len(c), 12)),
    (VKCScheduler, SerialVKCScheduler, lambda c: (c, 2)),
    (IKCScheduler, SerialIKCScheduler, lambda c: (c, 2)),
])
def test_selection_frequencies_match_serial(vec_cls, ser_cls, args_of):
    """Both engines must induce the same per-device selection law: run R
    rounds of each and compare every device's frequency against the
    other engine's within binomial noise (5 sigma)."""
    rng = np.random.default_rng(0)
    n, k = 60, 4
    clusters = rng.integers(0, k, n)
    clusters[:k] = np.arange(k)
    rounds = 800
    fv = _freqs(vec_cls, args_of(clusters), rounds, seed=1, n=n)
    fs = _freqs(ser_cls, args_of(clusters), rounds, seed=2, n=n)
    assert fv.sum() == fs.sum()                    # identical cohort sizes
    # binomial std of a per-device count, using the serial engine's
    # empirical rate as the reference law
    q = fs / rounds
    sigma = np.sqrt(rounds * q * (1 - q)).clip(min=1.0)
    assert np.all(np.abs(fv - fs) <= 5.0 * sigma), (
        np.abs(fv - fs) / sigma)


@pytest.mark.parametrize("cls", [IKCScheduler, SerialIKCScheduler])
def test_ikc_rotation_blocks_match_serial_invariant(cls):
    """With clusters an exact multiple of h, every cnt/h-round block is
    one rotation: each device scheduled exactly once — in BOTH engines."""
    rng = np.random.default_rng(11)
    per, k, h = 12, 5, 3
    clusters = np.repeat(np.arange(k), per)
    s = cls(clusters, h)
    for _ in range(3):                              # three full rotations
        counts = np.zeros(len(clusters), int)
        for _ in range(per // h):
            counts[s.schedule(rng)] += 1
        assert counts.min() == counts.max() == 1, counts


@pytest.mark.parametrize("cls", [IKCScheduler, SerialIKCScheduler])
@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_ikc_topup_respects_rotation(cls, seed):
    """Regression (ISSUE 6): devices scheduled through the top-up path
    must enter their cluster's rotation set G_k. Cluster 0 has a single
    device (short path, always scheduled), so each round's cohort is 2
    cluster-1 picks + 1 cluster-1 top-up: 18 devices / 3 per round = one
    full rotation in 6 rounds, with zero repeats. Pre-fix, top-up picks
    stayed in C_k and were re-pickable next round."""
    clusters = np.array([0] + [1] * 18)
    rng = np.random.default_rng(seed)
    s = cls(clusters, 2)                            # K=2, h=2, H=4
    counts = np.zeros(19, int)
    for _ in range(6):
        sel = s.schedule(rng)
        assert len(sel) == 4 and len(set(sel.tolist())) == 4
        counts[sel] += 1
    assert counts[0] == 6                           # the short cluster
    assert counts[1:].min() == counts[1:].max() == 1, counts


@pytest.mark.parametrize("cls", [VKCScheduler, SerialVKCScheduler,
                                 IKCScheduler, SerialIKCScheduler])
def test_empty_cluster_topup(cls):
    """A label gap (K' < K: cluster 1 has no members) must not crash and
    must still produce a full unique cohort via top-up."""
    clusters = np.array([0] * 5 + [2] * 5)          # K=3, cluster 1 empty
    rng = np.random.default_rng(5)
    s = cls(clusters, 2)
    for _ in range(4):
        sel = s.schedule(rng)
        assert len(sel) == 6
        assert len(set(sel.tolist())) == 6
        assert sel.min() >= 0 and sel.max() < 10


def test_sweep_short_cohort_topup_records_rotation():
    """The SweepRunner K' < K path calls ``topup_to`` beyond the
    scheduler's own H; for IKC the extra picks must land in G_k (the
    vectorized state's window tail) / the serial G_k list."""
    clusters = np.repeat(np.arange(3), 8)
    rng = np.random.default_rng(9)

    s = IKCScheduler(clusters, 2)
    sel = s.schedule(rng)
    topped = s.topup_to(sel, 10, rng)
    assert len(topped) == 10 and len(set(topped.tolist())) == 10
    extra = topped[len(sel):]
    st = s.state
    for d in extra:
        k = int(st.clusters[d])
        rel = int(st.pos[d]) - int(st.offsets[k])
        assert rel >= s.nf[k], (d, rel, s.nf[k])    # in the G_k window

    ser = SerialIKCScheduler(clusters, 2)
    sel = ser.schedule(rng)
    topped = ser.topup_to(sel, 10, rng)
    extra = topped[len(sel):]
    for d in extra:
        k = int(ser.clusters[d])
        assert d in ser.G[k] and d not in ser.C[k]


def test_vectorized_state_stays_consistent():
    """After many rounds (normal, refill and top-up paths all taken) the
    CSR state must remain a permutation with a correct inverse and
    cluster-respecting windows."""
    rng = np.random.default_rng(2)
    clusters = rng.integers(0, 6, 150)
    clusters[:6] = np.arange(6)
    s = IKCScheduler(clusters, 4)
    for _ in range(40):
        s.schedule(rng)
    st = s.state
    assert np.array_equal(np.sort(st.order), np.arange(150))
    assert np.array_equal(st.order[st.pos], np.arange(150))
    for k in range(6):
        win = st.order[st.offsets[k]:st.offsets[k + 1]]
        assert np.all(clusters[win] == k)
        assert 0 <= s.nf[k] <= st.counts[k]


def test_fedavg_permutation_fallback_uniform():
    """H > N/2 takes the materialized-pool path; still uniform and
    duplicate-free."""
    rng = np.random.default_rng(4)
    s = FedAvgScheduler(10, 8)
    f = np.zeros(10, int)
    for _ in range(500):
        sel = s.schedule(rng)
        assert len(set(sel.tolist())) == 8
        f[sel] += 1
    assert np.all(np.abs(f - 400) < 5 * np.sqrt(500 * 0.8 * 0.2))
