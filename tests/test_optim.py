"""Optimizers: convergence on a quadratic, state shapes, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adam, clip_by_global_norm, sgd
from repro.optim.schedules import constant, cosine, warmup_cosine


def _quadratic_target():
    rng = np.random.default_rng(0)
    target = {"w": jnp.asarray(rng.normal(0, 1, (8, 4))),
              "b": jnp.asarray(rng.normal(0, 1, (4,)))}
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))
    return params, loss


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
    lambda: adam(0.1), lambda: adafactor(0.3)])
def test_optimizer_converges(make_opt):
    params, loss = _quadratic_target()
    opt = make_opt()
    st = opt.init(params)
    l0 = float(loss(params))
    for _ in range(120):
        g = jax.grad(loss)(params)
        params, st = opt.update(g, st, params)
    assert float(loss(params)) < 0.05 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)
    small = {"a": jnp.full((3,), 0.01), "b": jnp.full((4,), 0.01)}
    unchanged = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(unchanged["a"]), 0.01, rtol=1e-5)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((16,))}
    st = adafactor(0.01).init(params)
    assert st["mom"]["w"]["vr"].shape == (64,)
    assert st["mom"]["w"]["vc"].shape == (32,)
    assert st["mom"]["v"]["v"].shape == (16,)


def test_schedules():
    c = constant(0.1)
    assert float(c(jnp.int32(5))) == pytest.approx(0.1)
    cs = cosine(1.0, 100, final_frac=0.1)
    assert float(cs(jnp.int32(0))) == pytest.approx(1.0, abs=1e-5)
    assert float(cs(jnp.int32(100))) == pytest.approx(0.1, abs=1e-5)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.int32(0))) == pytest.approx(0.0, abs=1e-6)
    assert float(wc(jnp.int32(10))) == pytest.approx(1.0, abs=1e-2)
