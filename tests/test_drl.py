"""D3QN agent: dueling identity, BiLSTM state semantics, replay, learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.drl.bilstm import bilstm_encode, bilstm_init
from repro.drl.d3qn import d3qn_init, q_values_all_t
from repro.drl.replay import EpisodeReplay

KEY = jax.random.PRNGKey(0)


def test_q_values_shape_and_dueling_identity():
    H, F, M = 10, 8, 5
    params = d3qn_init(KEY, F, M, hidden=32)
    feats = jax.random.normal(KEY, (H, F))
    q = q_values_all_t(params, feats)
    assert q.shape == (H, M)
    # dueling: mean-advantage subtraction => mean_a (Q - V) == 0
    enc = bilstm_encode(params["bilstm"], feats)
    z = jax.nn.relu(enc @ params["trunk"]["w"] + params["trunk"]["b"])
    v = z @ params["v_head"]["w"] + params["v_head"]["b"]
    np.testing.assert_allclose(np.asarray(jnp.mean(q - v, axis=-1)),
                               0.0, atol=1e-5)


def test_bilstm_state_depends_on_prefix_and_suffix():
    """Eq. (25): slot t's encoding must change if its prefix changes, and
    also if its suffix changes."""
    F = 6
    params = bilstm_init(KEY, F, 16)
    feats = jax.random.normal(KEY, (8, F))
    enc = bilstm_encode(params, feats)
    feats2 = feats.at[0].set(feats[0] + 1.0)      # change prefix of t=5
    enc2 = bilstm_encode(params, feats2)
    assert not np.allclose(np.asarray(enc[5]), np.asarray(enc2[5]))
    feats3 = feats.at[7].set(feats[7] + 1.0)      # change suffix of t=5
    enc3 = bilstm_encode(params, feats3)
    assert not np.allclose(np.asarray(enc[5]), np.asarray(enc3[5]))
    # forward half at t is unaffected by suffix change
    hidden = 16
    np.testing.assert_allclose(np.asarray(enc[5][:hidden]),
                               np.asarray(enc3[5][:hidden]), atol=1e-6)


def test_replay_episode_sampling():
    rep = EpisodeReplay(capacity_episodes=4)
    rng = np.random.default_rng(0)
    for e in range(6):                            # overwrites ring buffer
        rep.push(np.full((5, 3), e, np.float32), np.arange(5) % 2,
                 np.ones(5))
    assert rep.n_episodes == 4
    feats, ep_idx, slots, acts, rews = rep.sample(rng, 8)
    assert feats.ndim == 3 and len(slots) == len(acts) == len(rews)
    assert slots.max() < 5


def test_replay_ring_wraparound_batch_push():
    """push_batch ring semantics: after wrapping, the buffer holds the
    most recent `capacity` episodes and overwrites the oldest slots."""
    rep = EpisodeReplay(capacity_episodes=4)
    mk = lambda e: (np.full((3, 2), e, np.float32),  # noqa: E731
                    np.full(3, e % 2), np.full(3, float(e)))
    rep.push_batch(*[np.stack(a) for a in
                     zip(*(mk(e) for e in range(3)))])
    assert rep.n_episodes == 3 and len(rep) == 9
    rep.push_batch(*[np.stack(a) for a in
                     zip(*(mk(e) for e in range(3, 6)))])
    assert rep.n_episodes == 4 and len(rep) == 12
    # episodes 0 and 1 were overwritten (slots 0, 1 now hold 4, 5)
    held = sorted(int(rep._feats[i, 0, 0]) for i in range(4))
    assert held == [2, 3, 4, 5]
    # a push bigger than capacity keeps only the tail
    rep2 = EpisodeReplay(capacity_episodes=2)
    rep2.push_batch(*[np.stack(a) for a in
                      zip(*(mk(e) for e in range(5)))])
    assert rep2.n_episodes == 2
    assert sorted(int(rep2._feats[i, 0, 0]) for i in range(2)) == [3, 4]


def test_replay_sample_updates_shapes_and_consistency():
    """sample_updates: (U,) stacked minibatches with per-update distinct
    episodes, and gathered actions/rewards that match the stored arrays
    at the sampled (episode, slot) pairs."""
    rep = EpisodeReplay(capacity_episodes=8)
    rng = np.random.default_rng(1)
    for e in range(6):
        rep.push(np.full((4, 3), e, np.float32),
                 np.full(4, e), np.full(4, 10.0 * e))
    U, n_tuples = 3, 8
    feats, ep_idx, slots, acts, rews = rep.sample_updates(rng, U, n_tuples,
                                                          max_episodes=4)
    assert feats.shape == (U, 4, 4, 3)
    assert ep_idx.shape == slots.shape == acts.shape == rews.shape == (U, 8)
    assert slots.max() < 4 and ep_idx.max() < 4
    # gathered values are consistent with the episode stack: the episode
    # id was baked into feats/actions/rewards at push time
    for u in range(U):
        ep_of_tuple = feats[u, ep_idx[u], 0, 0]
        np.testing.assert_array_equal(acts[u], ep_of_tuple.astype(acts.dtype))
        np.testing.assert_array_equal(rews[u], 10.0 * ep_of_tuple)
        # without-replacement episode draw per update
        ids = feats[u, :, 0, 0]
        assert len(set(ids.tolist())) == 4


def test_replay_rejects_shape_changes_and_empty_sample():
    rep = EpisodeReplay(capacity_episodes=4)
    with pytest.raises(ValueError, match="empty"):
        rep.sample_updates(np.random.default_rng(0), 1, 4)
    rep.push(np.zeros((5, 3), np.float32), np.zeros(5), np.zeros(5))
    with pytest.raises(ValueError, match="episode shape"):
        rep.push(np.zeros((4, 3), np.float32), np.zeros(4), np.zeros(4))


@pytest.mark.slow
def test_d3qn_learns_fixed_target():
    """On a FIXED population with a fixed target assignment, the agent must
    learn to imitate it (reward -> positive) within a few hundred updates.

    ~100 s of serial act/update host loop — slow-marked, run with -m slow."""
    from repro.optim import adam
    from repro.drl.train import _td_loss
    H, F, M = 8, 7, 4
    params = d3qn_init(KEY, F, M, hidden=24)
    feats = np.asarray(jax.random.uniform(KEY, (H, F)))
    target_actions = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (H,), 0, M))
    opt = adam(3e-3)
    st = opt.init(params)
    rng = np.random.default_rng(0)

    @jax.jit
    def update(params, st, acts, rews):
        loss, g = jax.value_and_grad(_td_loss)(
            params, params, jnp.asarray(feats[None]),
            jnp.zeros(H, jnp.int32), jnp.arange(H), acts, rews, 0.9)
        params, st = opt.update(g, st, params)
        return params, st, loss

    for i in range(300):
        q = np.asarray(q_values_all_t(params, jnp.asarray(feats)))
        acts = q.argmax(-1)
        if rng.random() < max(0.05, 1 - i / 150):
            acts = rng.integers(0, M, H)
        rews = np.where(acts == target_actions, 1.0, -1.0)
        params, st, loss = update(params, st, jnp.asarray(acts),
                                  jnp.asarray(rews, jnp.float32))
    q = np.asarray(q_values_all_t(params, jnp.asarray(feats)))
    agreement = (q.argmax(-1) == target_actions).mean()
    assert agreement >= 0.7, agreement
