"""Event-driven async engine: degenerate-trace parity against the
synchronous ``round_step`` oracle, staleness/dropout/arrival behaviour,
and the availability-trace samplers (ISSUE 8 tentpole).

The parity contract (documented in ``docs/async.md``): with
``AvailabilityTrace.always_on`` + wait-for-all buffers + no jitter, the
event loop IS the synchronous round — allocations and per-task costs
bitwise, T_i/E_i to float-accumulation-order tolerance, trained params
and accuracy to ulp-level tolerance.
"""
import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")
import jax  # noqa: E402

from repro.core import cost_model as cm  # noqa: E402
from repro.core.async_engine import AsyncConfig, AsyncHFLEngine  # noqa: E402
from repro.core.framework import round_step  # noqa: E402
from repro.core.hfl import evaluate_in_batches  # noqa: E402
from repro.core.traffic import TrafficGenerator, TrafficParams  # noqa: E402
from repro.data import make_dataset, partition_noniid  # noqa: E402

N_DEV, N_EDGE, H = 10, 3, 6
ALLOC_STEPS = 60


class _FixedSched:
    """Deterministic cohort — isolates the event loop from scheduler RNG."""

    def __init__(self, sel):
        self.sel = np.asarray(sel)

    def schedule(self, rng):
        return self.sel


class _ModAssigner:
    """Round-robin assignment: guarantees every edge a known member set."""

    def assign(self, pop, sched, rng):
        return np.asarray(sched) % pop.n_edges, None


def _world(seed=0):
    # small Q/L keep the event loop fast; the loop structure is identical
    sp = cm.SystemParams(n_devices=N_DEV, n_edges=N_EDGE,
                         d_range=(30, 60), L=2, Q=3)
    pop = cm.sample_population(sp, seed=seed)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=300, n_test=120,
                                seed=seed)
    fed = partition_noniid(X, y, Xt, yt, n_devices=N_DEV,
                           size_range=(15, 25), seed=seed)
    return sp, pop, fed


# ------------------------------------------------------------- samplers

def test_always_on_trace_is_degenerate():
    tr = cm.AvailabilityTrace.always_on(5)
    for t in (0.0, 1.0, 1e9):
        assert tr.up_at(t).all()
    assert (tr.latency_scale == 1.0).all()
    assert tr.toggles_after(0, 0.0).size == 0


def test_default_params_sample_degenerate_trace():
    tr = cm.sample_availability(cm.AvailabilityParams(), 8, seed=1)
    assert tr.init_up.all()
    assert np.isinf(tr.toggles).all()
    assert (tr.latency_scale == 1.0).all()


def test_sampled_toggles_ascend_and_replay():
    ap = cm.AvailabilityParams(p_offline0=0.3, mean_up_s=50.0,
                               mean_down_s=10.0)
    tr = cm.sample_availability(ap, 64, seed=7, max_toggles=16)
    fin = np.where(np.isfinite(tr.toggles), tr.toggles, np.inf)
    assert (np.diff(fin, axis=1) >= 0).all()
    assert np.isfinite(tr.toggles).any()
    tr2 = cm.sample_availability(ap, 64, seed=7, max_toggles=16)
    np.testing.assert_array_equal(tr.toggles, tr2.toggles)
    np.testing.assert_array_equal(tr.init_up, tr2.init_up)


def test_straggler_scales_two_valued():
    ap = cm.AvailabilityParams(straggler_frac=0.5, straggler_scale=7.0)
    s = np.asarray(cm.sample_straggler_scales(
        jax.random.PRNGKey(0), ap, 200))
    assert set(np.unique(s)) == {1.0, 7.0}


def test_up_at_counts_flips():
    tr = cm.AvailabilityTrace(init_up=np.array([True]),
                              toggles=np.array([[1.0, 2.0, np.inf]]),
                              latency_scale=np.ones(1))
    assert tr.up_at(0.5)[0] and not tr.up_at(1.5)[0] and tr.up_at(2.5)[0]
    np.testing.assert_array_equal(tr.toggles_after(0, 0.5),
                                  np.array([1.0, 2.0]))


# ----------------------------------------------------- oracle parity

def test_degenerate_trace_matches_round_step_oracle():
    """Zero-latency-skew/zero-dropout async == synchronous round_step:
    allocations bitwise, costs to accumulation-order tolerance, params
    and accuracy to ulp-ish tolerance — over multiple rounds."""
    sp, pop, fed = _world(seed=0)
    cfg = AsyncConfig(H=H, scheduler="fedavg", alloc_steps=ALLOC_STEPS,
                      seed=3)
    eng = AsyncHFLEngine(sp, pop, fed, cfg)
    spp = eng.sp                       # model_bits-patched params
    params_sync = eng.model_params     # identical start state

    for _ in range(2):
        rec = eng.step_round()
        sched, assign = eng.last_sched, eng.last_assign
        params_sync, (T, E, _, _, b, f) = round_step(
            eng.apply_fn, spp, params_sync,
            pop.u[sched], pop.D[sched], pop.p[sched], pop.g[sched],
            pop.g_cloud, pop.B_m,
            eng.X[sched], eng.y[sched], eng.mask[sched],
            pop.D[sched], jnp.asarray(assign, jnp.int32), cfg.lr,
            M=pop.n_edges, L=spp.L, Q=spp.Q, alloc_steps=cfg.alloc_steps)

        b_a, f_a = eng.last_alloc[:2]
        np.testing.assert_array_equal(np.asarray(b_a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f))
        assert rec["T_i"] == pytest.approx(float(T), rel=1e-5)
        assert rec["E_i"] == pytest.approx(float(E), rel=1e-5)
        assert rec["n_updates"] == spp.Q * H
        assert rec["n_stale"] == 0 and rec["n_aborted"] == 0
        assert rec["forced_flushes"] == 0
        assert rec["msg_bits"] == pytest.approx(
            (spp.Q * H + pop.n_edges) * spp.model_bits)
        for pa, pb in zip(jax.tree.leaves(eng.model_params),
                          jax.tree.leaves(params_sync)):
            np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                       rtol=2e-6, atol=2e-7)
        acc_sync = evaluate_in_batches(eng.apply_fn, params_sync,
                                       fed.X_test, fed.y_test)
        assert rec["acc"] == pytest.approx(acc_sync, abs=1e-6)


# -------------------------------------------------- async behaviour

def _straggler_trace(sp, pop, fed, seed):
    """Latency scales making slots 3..5 deliver at 1.5x their edge's
    fast member — after the first buffered flush, before the edge's Q-th
    — so staleness >= 1 is guaranteed, not timing-dependent."""
    probe = AsyncHFLEngine(sp, pop, fed,
                           AsyncConfig(H=H, alloc_steps=ALLOC_STEPS,
                                       seed=seed),
                           scheduler=_FixedSched(np.arange(H)),
                           assigner=_ModAssigner())
    probe.step_round(collect_eval=False)
    tc = np.asarray(probe.last_alloc[2], np.float64)
    scale = np.ones(N_DEV)
    for s in range(3, 6):              # slot s shares an edge with s-3
        scale[s] = 1.5 * tc[s - 3] / tc[s]
    return cm.AvailabilityTrace(init_up=np.ones(N_DEV, bool),
                                toggles=np.full((N_DEV, 1), np.inf),
                                latency_scale=scale)


def test_stragglers_with_small_buffer_cause_staleness_and_finish_early():
    sp, pop, fed = _world(seed=1)
    tr = _straggler_trace(sp, pop, fed, seed=5)

    def build(buffer_size):
        cfg = AsyncConfig(H=H, alloc_steps=ALLOC_STEPS, seed=5,
                          buffer_size=buffer_size, staleness_exp=0.5)
        return AsyncHFLEngine(sp, pop, fed, cfg, trace=tr,
                              scheduler=_FixedSched(np.arange(H)),
                              assigner=_ModAssigner())

    rec_buf = build(1).step_round(collect_eval=False)
    rec_all = build(None).step_round(collect_eval=False)
    # FedBuff-style flushes aggregate late updates at staleness >= 1 ...
    assert rec_buf["n_stale"] > 0 and rec_buf["max_staleness"] >= 1
    # ... and stop waiting on the stragglers' critical path
    assert rec_buf["T_i"] < rec_all["T_i"]
    # wait-for-all never sees staleness, only a longer round
    assert rec_all["n_stale"] == 0


def test_all_offline_round_terminates_and_keeps_model():
    sp, pop, fed = _world(seed=2)
    tr = cm.AvailabilityTrace(init_up=np.zeros(N_DEV, bool),
                              toggles=np.full((N_DEV, 1), np.inf),
                              latency_scale=np.ones(N_DEV))
    cfg = AsyncConfig(H=H, alloc_steps=ALLOC_STEPS, seed=0)
    eng = AsyncHFLEngine(sp, pop, fed, cfg, trace=tr)
    before = jax.tree.map(np.asarray, eng.model_params)
    rec = eng.step_round(collect_eval=False)
    assert rec["n_updates"] == 0
    assert rec["forced_flushes"] > 0
    for pa, pb in zip(jax.tree.leaves(before),
                      jax.tree.leaves(eng.model_params)):
        np.testing.assert_allclose(pa, np.asarray(pb), rtol=1e-6,
                                   atol=1e-7)


def test_late_arrivals_still_deliver_full_round():
    """Whole fleet offline at t=0; Exp(1s) arrivals then stay up — the
    round starts late but every edge still drains Q full buffers."""
    sp, pop, fed = _world(seed=3)
    ap = cm.AvailabilityParams(p_offline0=1.0, mean_down_s=1.0,
                               mean_up_s=float("inf"))
    tr = cm.sample_availability(ap, N_DEV, seed=11)
    assert not tr.init_up.any()
    cfg = AsyncConfig(H=H, alloc_steps=ALLOC_STEPS, seed=4)
    eng = AsyncHFLEngine(sp, pop, fed, cfg, trace=tr,
                         scheduler=_FixedSched(np.arange(H)),
                         assigner=_ModAssigner())
    rec = eng.step_round(collect_eval=False)
    assert rec["n_updates"] == sp.Q * H
    assert rec["forced_flushes"] == 0


def test_churny_round_terminates_with_sane_accounting():
    sp, pop, fed = _world(seed=4)
    cfg0 = AsyncConfig(H=H, alloc_steps=ALLOC_STEPS, seed=6)
    probe = AsyncHFLEngine(sp, pop, fed, cfg0)
    T_deg = probe.step_round(collect_eval=False)["T_i"]

    ap = cm.AvailabilityParams(p_offline0=0.2, mean_up_s=T_deg / 5,
                               mean_down_s=T_deg / 10)
    tr = cm.sample_availability(ap, N_DEV, seed=13, max_toggles=256)
    cfg = AsyncConfig(H=H, alloc_steps=ALLOC_STEPS, seed=6,
                      buffer_size=1)
    eng = AsyncHFLEngine(sp, pop, fed, cfg, trace=tr)
    summary = eng.run(n_rounds=2, eval_every=2)
    assert summary["rounds"] == 2
    assert summary["n_updates"] <= 2 * sp.Q * H
    assert summary["wasted_j"] >= 0.0
    assert eng.t > 0.0
    assert summary["final_acc"] is not None


def test_staleness_weight_decay_dampens_stale_updates():
    """A stale delivery moves the edge model less than a fresh one:
    larger a => stronger decay => smaller parameter step."""
    sp, pop, fed = _world(seed=5)
    tr = _straggler_trace(sp, pop, fed, seed=7)

    def run(a):
        cfg = AsyncConfig(H=H, alloc_steps=ALLOC_STEPS, seed=7,
                          buffer_size=1, staleness_exp=a)
        eng = AsyncHFLEngine(sp, pop, fed, cfg, trace=tr,
                             scheduler=_FixedSched(np.arange(H)),
                             assigner=_ModAssigner())
        rec = eng.step_round(collect_eval=False)
        assert rec["n_stale"] > 0      # decay actually exercised
        return jax.tree.leaves(jax.tree.map(np.asarray, eng.model_params))

    base = run(0.0)
    damped = run(4.0)
    diff = sum(float(np.abs(a - b).sum()) for a, b in zip(base, damped))
    assert diff > 0.0                  # a changes the aggregate


# ------------------------------------------------------------ traffic

def test_traffic_trace_respects_horizon_and_seeds():
    tp = TrafficParams(join_rate=0.5, mean_session_s=20.0, p_online0=0.3)
    gen = TrafficGenerator(tp, n_devices=12, seed=9)
    tr = gen.make_trace(horizon_s=100.0)
    fin = tr.toggles[np.isfinite(tr.toggles)]
    assert fin.size > 0 and (fin >= 0).all() and (fin <= 100.0).all()
    np.testing.assert_array_equal(tr.up_at(0.0), tr.init_up)
    tr2 = TrafficGenerator(tp, n_devices=12, seed=9).make_trace(100.0)
    np.testing.assert_array_equal(tr.toggles, tr2.toggles)


def test_traffic_rate_modulation():
    tp = TrafficParams(join_rate=1.0, diurnal_amp=0.5,
                       diurnal_period_s=100.0, burst_mult=4.0,
                       burst_every_s=50.0, burst_len_s=5.0)
    gen = TrafficGenerator(tp, n_devices=4, seed=0)
    assert gen.rate(25.0) == pytest.approx(1.5)      # diurnal peak
    assert gen.rate(75.0) == pytest.approx(0.5)      # diurnal trough
    assert gen.rate(51.0) == pytest.approx(
        4.0 * (1.0 + 0.5 * np.sin(2 * np.pi * 51.0 / 100.0)))
    assert gen.rate(0.0) == pytest.approx(4.0)       # burst at t=0


def test_traffic_trace_drives_engine():
    sp, pop, fed = _world(seed=6)
    probe = AsyncHFLEngine(sp, pop, fed,
                           AsyncConfig(H=H, alloc_steps=ALLOC_STEPS))
    T_deg = probe.step_round(collect_eval=False)["T_i"]
    tp = TrafficParams(join_rate=2.0 / T_deg, mean_session_s=T_deg,
                       p_online0=0.5)
    tr = TrafficGenerator(tp, N_DEV, seed=3).make_trace(5 * T_deg)
    eng = AsyncHFLEngine(sp, pop, fed,
                         AsyncConfig(H=H, alloc_steps=ALLOC_STEPS,
                                     buffer_size=2), trace=tr)
    rec = eng.step_round(collect_eval=False)
    assert rec["round"] == 1 and rec["T_i"] > 0.0
