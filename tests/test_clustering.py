"""K-means + ARI: recovery of separated clusters, ARI invariances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.clustering import adjusted_rand_index, kmeans, kmeans_best_of

KEY = jax.random.PRNGKey(0)


def test_kmeans_recovers_separated_clusters():
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 10, (4, 8))
    labels_true = np.repeat(np.arange(4), 25)
    x = centers[labels_true] + rng.normal(0, 0.3, (100, 8))
    lab, cen = kmeans_best_of(KEY, jnp.asarray(x), 4, restarts=4)
    assert adjusted_rand_index(np.asarray(lab), labels_true) == 1.0


def test_kmeans_inertia_decreases_with_k():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (60, 5)))
    def inertia(k):
        lab, cen = kmeans(KEY, x, k, iters=30)
        from repro.core.clustering import pairwise_sq_dists
        return float(jnp.sum(jnp.min(pairwise_sq_dists(x, cen), axis=1)))
    assert inertia(8) <= inertia(2) + 1e-5


def test_ari_identical_is_one():
    lab = np.array([0, 0, 1, 1, 2, 2])
    assert adjusted_rand_index(lab, lab) == 1.0


def test_ari_permutation_invariant():
    truth = np.array([0, 0, 1, 1, 2, 2])
    pred = np.array([2, 2, 0, 0, 1, 1])    # same partition, renamed
    assert adjusted_rand_index(pred, truth) == 1.0


@given(st.lists(st.integers(0, 3), min_size=8, max_size=40))
@settings(max_examples=30, deadline=None)
def test_ari_symmetric_and_bounded(labels):
    a = np.array(labels)
    rng = np.random.default_rng(0)
    b = rng.integers(0, 4, len(a))
    ab = adjusted_rand_index(a, b)
    ba = adjusted_rand_index(b, a)
    assert ab == pytest.approx(ba, abs=1e-9)
    assert ab <= 1.0 + 1e-9


def test_ari_random_near_zero():
    rng = np.random.default_rng(0)
    vals = []
    for s in range(20):
        a = rng.integers(0, 5, 200)
        b = rng.integers(0, 5, 200)
        vals.append(adjusted_rand_index(a, b))
    assert abs(np.mean(vals)) < 0.05


def test_pallas_kernel_path_matches_jnp_path():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (50, 64)).astype(np.float32))
    lab1, _ = kmeans(KEY, x, 5, iters=20, use_kernel=False)
    lab2, _ = kmeans(KEY, x, 5, iters=20, use_kernel=True)
    assert adjusted_rand_index(np.asarray(lab1), np.asarray(lab2)) == 1.0
