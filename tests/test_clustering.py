"""K-means + ARI: recovery of separated clusters, ARI invariances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.clustering import adjusted_rand_index, kmeans, kmeans_best_of

KEY = jax.random.PRNGKey(0)


def test_kmeans_recovers_separated_clusters():
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 10, (4, 8))
    labels_true = np.repeat(np.arange(4), 25)
    x = centers[labels_true] + rng.normal(0, 0.3, (100, 8))
    lab, cen = kmeans_best_of(KEY, jnp.asarray(x), 4, restarts=4)
    assert adjusted_rand_index(np.asarray(lab), labels_true) == 1.0


def test_kmeans_inertia_decreases_with_k():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (60, 5)))
    def inertia(k):
        lab, cen = kmeans(KEY, x, k, iters=30)
        from repro.core.clustering import pairwise_sq_dists
        return float(jnp.sum(jnp.min(pairwise_sq_dists(x, cen), axis=1)))
    assert inertia(8) <= inertia(2) + 1e-5


def test_ari_identical_is_one():
    lab = np.array([0, 0, 1, 1, 2, 2])
    assert adjusted_rand_index(lab, lab) == 1.0


def test_ari_permutation_invariant():
    truth = np.array([0, 0, 1, 1, 2, 2])
    pred = np.array([2, 2, 0, 0, 1, 1])    # same partition, renamed
    assert adjusted_rand_index(pred, truth) == 1.0


@given(st.lists(st.integers(0, 3), min_size=8, max_size=40))
@settings(max_examples=30, deadline=None)
def test_ari_symmetric_and_bounded(labels):
    a = np.array(labels)
    rng = np.random.default_rng(0)
    b = rng.integers(0, 4, len(a))
    ab = adjusted_rand_index(a, b)
    ba = adjusted_rand_index(b, a)
    assert ab == pytest.approx(ba, abs=1e-9)
    assert ab <= 1.0 + 1e-9


def test_ari_random_near_zero():
    rng = np.random.default_rng(0)
    vals = []
    for s in range(20):
        a = rng.integers(0, 5, 200)
        b = rng.integers(0, 5, 200)
        vals.append(adjusted_rand_index(a, b))
    assert abs(np.mean(vals)) < 0.05


def test_ari_large_n_no_int64_overflow():
    """Regression (ISSUE 6): at N=2e5 with few clusters the pair-count
    product a*b passes int64 max and silently overflowed pre-fix. Must
    match the direct float-promoted formula exactly."""
    rng = np.random.default_rng(0)
    n = 200_000
    a = rng.integers(0, 3, n)
    b = np.where(rng.random(n) < 0.2, rng.integers(0, 3, n), a)
    got = adjusted_rand_index(a, b)

    # direct float formula on the same contingency table
    cont = np.zeros((3, 3))
    np.add.at(cont, (a, b), 1)
    def c2(v):
        v = v.astype(np.float64)
        return v * (v - 1) / 2
    sum_ij = c2(cont).sum()
    ai = c2(cont.sum(axis=1)).sum()
    bj = c2(cont.sum(axis=0)).sum()
    total = n * (n - 1) / 2
    want = (sum_ij - ai * bj / total) / ((ai + bj) / 2 - ai * bj / total)
    assert got == pytest.approx(want, rel=1e-9)
    assert 0.5 < got < 0.9                 # ~80% agreement, 3 clusters


def test_kmeans_pp_init_threads_kernel_flag(monkeypatch):
    """Regression (ISSUE 6): kmeans(use_kernel=True) must take the
    kernel distance path during kmeans++ init too, not only in the Lloyd
    steps (pre-fix the init call dropped the flag)."""
    import repro.core.clustering as cl
    calls = []
    real = cl.pairwise_sq_dists

    def spy(x, c, use_kernel=False):
        calls.append(use_kernel)
        return real(x, c, use_kernel=use_kernel)

    monkeypatch.setattr(cl, "pairwise_sq_dists", spy)
    # unique shapes so the jit cache cannot serve a pre-spy trace
    x = jnp.asarray(np.random.default_rng(0).normal(size=(33, 17)),
                    dtype=jnp.float32)
    cl.kmeans(jax.random.PRNGKey(1), x, 3, iters=2, use_kernel=True)
    assert calls, "spy never saw a distance call"
    assert all(calls), f"init/step dropped use_kernel: {calls}"


def test_pallas_kernel_path_matches_jnp_path():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (50, 64)).astype(np.float32))
    lab1, _ = kmeans(KEY, x, 5, iters=20, use_kernel=False)
    lab2, _ = kmeans(KEY, x, 5, iters=20, use_kernel=True)
    assert adjusted_rand_index(np.asarray(lab1), np.asarray(lab2)) == 1.0
