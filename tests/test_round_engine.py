"""Fused batched round engine: parity against the sequential oracles.

The batched allocator (``allocate_all_edges``) and the fused
``round_step`` replace the seed's per-edge Python loops; these tests pin
them to the original per-edge path on identical inputs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import resource as ra
from repro.core.framework import (FrameworkConfig, HFLFramework,
                                  round_step)
from repro.core.hfl import hfl_global_iteration
from repro.core.sweep import SweepRunner

ALLOC_STEPS = 120


def _per_edge_oracle(sp, pop, sched, assign, steps):
    """The seed's sequential loop: M separate allocate calls."""
    outs = []
    for m in range(pop.n_edges):
        mask = jnp.asarray(assign == m)
        outs.append(ra.allocate(sp, pop.u[sched], pop.D[sched],
                                pop.p[sched], pop.g[sched, m],
                                pop.B_m[m], mask, steps=steps))
    return outs


@pytest.mark.parametrize("seed", [1, 7])
def test_allocate_all_edges_matches_per_edge_loop(seed):
    """Batched solve == per-edge loop on b, f, T_edge, E_edge to 1e-5,
    including populations where some edges receive no devices."""
    sp = cm.SystemParams(n_devices=18, n_edges=4)
    pop = cm.sample_population(sp, seed=seed)
    sched = np.arange(18)
    rng = np.random.default_rng(seed)
    # only 3 of 4 edges used -> edge 3 is empty
    assign = rng.integers(0, 3, 18)

    seq = _per_edge_oracle(sp, pop, sched, assign, ALLOC_STEPS)
    bat = ra.allocate_all_edges(sp, pop, sched, assign, steps=ALLOC_STEPS)

    np.testing.assert_allclose(np.stack([np.asarray(r.b) for r in seq]),
                               np.asarray(bat.b), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.stack([np.asarray(r.f) for r in seq]),
                               np.asarray(bat.f), rtol=1e-5)
    np.testing.assert_allclose([float(r.T_edge) for r in seq],
                               np.asarray(bat.T_edge), rtol=1e-5)
    np.testing.assert_allclose([float(r.E_edge) for r in seq],
                               np.asarray(bat.E_edge), rtol=1e-5)
    # empty edge contributes nothing
    assert float(bat.T_edge[3]) == 0.0 and float(bat.obj[3]) == 0.0


def test_select_device_allocation_routes_rows():
    sp = cm.SystemParams(n_devices=10, n_edges=3)
    pop = cm.sample_population(sp, seed=2)
    sched = np.arange(10)
    assign = np.asarray([0, 1, 2, 0, 1, 2, 0, 1, 2, 0])
    bat = ra.allocate_all_edges(sp, pop, sched, assign, steps=60)
    b, f = ra.select_device_allocation(bat, assign)
    for h in range(10):
        assert float(b[h]) == float(bat.b[assign[h], h])
        assert float(f[h]) == float(bat.f[assign[h], h])


def _linear_apply(params, X):
    return X.reshape(X.shape[0], -1) @ params["w"]


def test_fused_round_step_matches_sequential_components():
    """One fused round_step == the sequential composition (per-edge
    allocate loop -> round_cost -> hfl_global_iteration) on T_i, E_i and
    the trained parameters, at fixed seed."""
    sp = cm.SystemParams(n_devices=12, n_edges=3)
    pop = cm.sample_population(sp, seed=4)
    rng = np.random.default_rng(4)
    sched = np.arange(12)
    assign = rng.integers(0, 3, 12)
    H, Dmax = 12, 6
    X = jnp.asarray(rng.normal(0, 1, (H, Dmax, 2, 2, 1)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, (H, Dmax)).astype(np.int32))
    mask = jnp.ones((H, Dmax), jnp.float32)
    w0 = {"w": jnp.asarray(rng.normal(0, 0.1, (4, 3)).astype(np.float32))}

    # sequential oracle
    seq = _per_edge_oracle(sp, pop, sched, assign, ALLOC_STEPS)
    b = np.zeros(H)
    f = np.zeros(H)
    for m, res in enumerate(seq):
        sel = assign == m
        b[sel] = np.asarray(res.b)[sel]
        f[sel] = np.asarray(res.f)[sel]
    T_i, E_i, _, _ = cm.round_cost(sp, pop, jnp.asarray(sched),
                                   jnp.asarray(assign), jnp.asarray(b),
                                   jnp.asarray(f))
    w_seq = hfl_global_iteration(_linear_apply, w0, X, y, mask,
                                 pop.D[sched], jnp.asarray(assign),
                                 M=3, L=2, Q=2, lr=0.05)

    # fused engine
    w_fused, (T_f, E_f, _, _, b_f, f_f) = round_step(
        _linear_apply, sp, w0, pop.u[sched], pop.D[sched], pop.p[sched],
        pop.g[sched], pop.g_cloud, pop.B_m, X, y, mask, pop.D[sched],
        jnp.asarray(assign), 0.05, M=3, L=2, Q=2, alloc_steps=ALLOC_STEPS)

    np.testing.assert_allclose(float(T_i), float(T_f), rtol=1e-5)
    np.testing.assert_allclose(float(E_i), float(E_f), rtol=1e-5)
    np.testing.assert_allclose(b, np.asarray(b_f), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(f, np.asarray(f_f), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(w_seq["w"]),
                               np.asarray(w_fused["w"]), rtol=1e-5,
                               atol=1e-7)


def _toy_round_inputs(seed=4, H=12, M=3, Dmax=6):
    sp = cm.SystemParams(n_devices=H, n_edges=M)
    pop = cm.sample_population(sp, seed=seed)
    rng = np.random.default_rng(seed)
    sched = np.arange(H)
    # leave edge M-1 empty: the kernel path must reproduce the einsum
    # path's empty-edge semantics (edge keeps its model, cloud weight 0)
    assign = rng.integers(0, M - 1, H)
    X = jnp.asarray(rng.normal(0, 1, (H, Dmax, 2, 2, 1)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, (H, Dmax)).astype(np.int32))
    mask = jnp.ones((H, Dmax), jnp.float32)
    w0 = {"w": jnp.asarray(rng.normal(0, 0.1, (4, 3)).astype(np.float32))}
    return sp, pop, sched, assign, X, y, mask, w0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hfl_iteration_agg_kernel_matches_einsum(dtype):
    """Algorithm 1 with agg_kernel=True == the einsum oracle, including
    an empty edge and both model dtypes."""
    sp, pop, sched, assign, X, y, mask, w0 = _toy_round_inputs()
    w0 = {"w": w0["w"].astype(dtype)}
    outs = {}
    for ak in (False, True):
        w = hfl_global_iteration(_linear_apply, w0, X.astype(dtype), y,
                                 mask, pop.D[sched], jnp.asarray(assign),
                                 M=3, L=2, Q=2, lr=0.05, agg_kernel=ak)
        outs[ak] = np.asarray(w["w"], np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 0.03
    np.testing.assert_allclose(outs[True], outs[False], rtol=tol, atol=tol)


def test_round_step_agg_kernel_matches_einsum_oracle():
    """Fused round_step with the Pallas aggregation backend == the
    einsum backend on trained params AND the cost outputs (which must be
    untouched by the aggregation route)."""
    sp, pop, sched, assign, X, y, mask, w0 = _toy_round_inputs()
    outs = {}
    for ak in (False, True):
        w, (T_i, E_i, _, _, b, f) = round_step(
            _linear_apply, sp, w0, pop.u[sched], pop.D[sched],
            pop.p[sched], pop.g[sched], pop.g_cloud, pop.B_m, X, y, mask,
            pop.D[sched], jnp.asarray(assign), 0.05, M=3, L=2, Q=2,
            alloc_steps=ALLOC_STEPS, agg_kernel=ak)
        outs[ak] = (np.asarray(w["w"]), float(T_i), float(E_i),
                    np.asarray(b), np.asarray(f))
    np.testing.assert_allclose(outs[True][0], outs[False][0],
                               rtol=1e-5, atol=1e-6)
    # the cost subgraph is identical, but the two agg_kernel traces are
    # separate XLA compilations — tight tolerance, not bitwise equality
    np.testing.assert_allclose(outs[True][1], outs[False][1], rtol=1e-6)
    np.testing.assert_allclose(outs[True][2], outs[False][2], rtol=1e-6)
    np.testing.assert_allclose(outs[True][3], outs[False][3],
                               rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(outs[True][4], outs[False][4], rtol=1e-6)


def test_sweep_round_agg_kernel_vmapped_lanes():
    """The vmapped multi-lane round with agg_kernel=True (one lane-
    batched kernel launch per aggregation) == the einsum lanes."""
    from repro.core.sweep import sweep_round
    sp, pop, sched, assign, X, y, mask, w0 = _toy_round_inputs()
    S = 2
    rng = np.random.default_rng(11)
    stack = lambda a: jnp.stack([jnp.asarray(a)] * S)  # noqa: E731
    params_b = {"w": jnp.asarray(
        rng.normal(0, 0.1, (S, 4, 3)).astype(np.float32))}
    assign_b = jnp.asarray(np.stack([assign,
                                     rng.integers(0, 3, len(sched))]))
    args = (_linear_apply, sp, params_b, stack(pop.u), stack(pop.D),
            stack(pop.p), stack(pop.g), stack(pop.g_cloud), stack(pop.B_m),
            stack(X), stack(y), stack(mask), stack(pop.D), stack(sched),
            assign_b, 0.05)
    kw = dict(M=3, L=2, Q=2, alloc_steps=60)
    p_k, (T_k, E_k) = sweep_round(*args, **kw, agg_kernel=True)
    p_e, (T_e, E_e) = sweep_round(*args, **kw, agg_kernel=False)
    np.testing.assert_allclose(np.asarray(p_k["w"]), np.asarray(p_e["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(T_k), np.asarray(T_e), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(E_k), np.asarray(E_e), rtol=1e-6)


def test_sweep_round_done_mask_freezes_lane():
    """Per-lane early stop: a done lane's params pass through unchanged
    and its training-compute costs (T_i, E_i) come back zero, while live
    lanes are untouched by the mask."""
    from repro.core.sweep import sweep_round
    sp, pop, sched, assign, X, y, mask, w0 = _toy_round_inputs()
    S = 2
    rng = np.random.default_rng(13)
    stack = lambda a: jnp.stack([jnp.asarray(a)] * S)  # noqa: E731
    params_b = {"w": jnp.asarray(
        rng.normal(0, 0.1, (S, 4, 3)).astype(np.float32))}
    assign_b = jnp.asarray(np.stack([assign, assign]))
    args = (_linear_apply, sp, params_b, stack(pop.u), stack(pop.D),
            stack(pop.p), stack(pop.g), stack(pop.g_cloud), stack(pop.B_m),
            stack(X), stack(y), stack(mask), stack(pop.D), stack(sched),
            assign_b, 0.05)
    kw = dict(M=3, L=2, Q=2, alloc_steps=60)
    p_all, (T_all, E_all) = sweep_round(*args, **kw)
    p_msk, (T_msk, E_msk) = sweep_round(
        *args, **kw, done_b=jnp.asarray([True, False]))
    # lane 0 frozen: params unchanged, zero costs
    np.testing.assert_array_equal(np.asarray(p_msk["w"][0]),
                                  np.asarray(params_b["w"][0]))
    assert float(T_msk[0]) == 0.0 and float(E_msk[0]) == 0.0
    # lane 1 live: identical to the unmasked round
    np.testing.assert_allclose(np.asarray(p_msk["w"][1]),
                               np.asarray(p_all["w"][1]), rtol=1e-6)
    np.testing.assert_allclose(float(T_msk[1]), float(T_all[1]), rtol=1e-6)
    np.testing.assert_allclose(float(E_msk[1]), float(E_all[1]), rtol=1e-6)


@pytest.mark.slow
def test_sweep_runner_per_lane_early_stop(small_world):
    """target_acc=0 marks every lane done after round 1: the run stops
    early and later rows never accrue costs (here there are none)."""
    sp, pop, fed = small_world
    from repro.core.scheduling import FedAvgScheduler
    runner = SweepRunner(sp, [(pop, fed), (pop, fed)], lr=0.01,
                         alloc_steps=50, model_seed=0)
    scheds = [FedAvgScheduler(fed.n_devices, 8) for _ in range(2)]
    out = runner.run(scheds, n_rounds=4, assign="geo", seeds=[0, 1],
                     target_acc=0.0)
    assert out["acc"].shape == (2, 1)          # stopped after one round
    np.testing.assert_array_equal(out["iters"], [1, 1])


@pytest.mark.slow
def test_sweep_runner_agg_kernel_matches_einsum(small_world):
    """End-to-end SweepRunner lane sweep: agg_kernel=True reproduces the
    einsum runner's accuracy/cost trajectories at fixed seeds."""
    sp, pop, fed = small_world
    from repro.core.scheduling import FedAvgScheduler
    outs = {}
    for ak in (False, True):
        runner = SweepRunner(sp, [(pop, fed), (pop, fed)], lr=0.01,
                             alloc_steps=50, model_seed=0, agg_kernel=ak)
        scheds = [FedAvgScheduler(fed.n_devices, 8) for _ in range(2)]
        outs[ak] = runner.run(scheds, n_rounds=2, assign="geo",
                              seeds=[0, 1])
    np.testing.assert_allclose(outs[True]["acc"], outs[False]["acc"],
                               atol=1e-6)
    np.testing.assert_allclose(outs[True]["T_i"], outs[False]["T_i"],
                               rtol=1e-6)
    np.testing.assert_allclose(outs[True]["E_i"], outs[False]["E_i"],
                               rtol=1e-6)


@pytest.mark.slow
def test_fused_framework_round_matches_sequential_record(small_world):
    """Framework-level regression: engine='fused' reproduces the
    sequential run_round record (T_i, E_i, acc) at fixed seed."""
    sp, pop, fed = small_world
    recs = {}
    for engine in ("sequential", "fused"):
        cfg = FrameworkConfig(scheduler="fedavg", assigner="geo", H=10,
                              K=10, target_acc=0.99, max_iters=1,
                              alloc_steps=60, seed=0, engine=engine)
        fw = HFLFramework(sp, pop, fed, cfg)
        recs[engine] = fw.run_round(1)
    for k in ("T_i", "E_i"):
        np.testing.assert_allclose(recs["sequential"][k],
                                   recs["fused"][k], rtol=1e-5)
    np.testing.assert_allclose(recs["sequential"]["acc"],
                               recs["fused"]["acc"], atol=1e-6)


@pytest.mark.slow
def test_sweep_runner_matches_fused_framework(small_world):
    """A 2-lane SweepRunner run is finite, shape-correct, and its lane-0
    records match a standalone fused framework driven by the same
    schedule/assignment/model-init stream."""
    sp, pop, fed = small_world
    runner = SweepRunner(sp, [(pop, fed), (pop, fed)], lr=0.01,
                         alloc_steps=50, model_seed=0)
    from repro.core.scheduling import FedAvgScheduler
    scheds = [FedAvgScheduler(fed.n_devices, 8) for _ in range(2)]
    out = runner.run(scheds, n_rounds=2, assign="geo", seeds=[0, 1])
    assert out["acc"].shape == (2, 2)
    assert out["T_i"].shape == (2, 2) and out["E_i"].shape == (2, 2)
    assert np.isfinite(out["T_i"]).all() and np.isfinite(out["E_i"]).all()
    assert (out["T_i"] > 0).all() and (out["E_i"] > 0).all()
    assert ((out["acc"] >= 0) & (out["acc"] <= 1)).all()
    assert out["H"] == 8
    assert out["msg_bits_per_round"] > 0
