"""Distribution-layer smoke test: lower/compile smoke configs on a small
multi-device mesh in a SUBPROCESS (device count must be set before jax
init, so it cannot run in-process with the other tests)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import InputShape
from repro.configs.registry import get_smoke_config
from repro.launch import steps as S

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}
for arch in ("chatglm3-6b", "qwen3-moe-235b-a22b", "mamba2-2.7b",
             "jamba-1.5-large-398b", "musicgen-medium", "internvl2-26b"):
    cfg = get_smoke_config(arch)
    shape = InputShape("smoke_train", 64, 8, "train")
    with mesh:
        step, opt = S.make_train_step(cfg, mesh)
        ps = S.params_struct(cfg, mesh)
        os_ = S.opt_state_struct(cfg, mesh, opt)
        batch = S.input_specs(cfg, shape, mesh)
        compiled = jax.jit(step).lower(ps, os_, batch).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out[arch + "/train"] = float(cost.get("flops", 0))
    dshape = InputShape("smoke_decode", 64, 8, "decode")
    with mesh:
        serve = S.make_serve_step(cfg, mesh)
        ps = S.params_struct(cfg, mesh)
        cache = S.cache_specs_struct(cfg, dshape, mesh)
        ins = S.input_specs(cfg, dshape, mesh)
        compiled = jax.jit(serve).lower(ps, cache, ins["tokens"],
                                        ins["pos"]).compile()
        out[arch + "/decode"] = 1.0
print(json.dumps(out))
"""


@pytest.mark.slow
def test_smoke_mesh_lowering():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(out) == 12
    assert all(v > 0 for v in out.values())
