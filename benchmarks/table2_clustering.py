"""Table II: time delay / energy for Algorithm 2 + ARI — IKC vs VKC."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit, make_world
from repro.core.clustering import adjusted_rand_index
from repro.core.hfl import pad_device_data
from repro.core.scheduling import run_device_clustering
from repro.core.scheduling.device_clustering import clustering_cost
from repro.models import cnn
from repro.utils import tree_bytes


def run(use_kernel: bool = False) -> None:
    key = jax.random.PRNGKey(0)
    rows = []
    for dataset in ("fmnist_syn", "cifar_syn"):
        sp, pop, fed = make_world(dataset)
        X, y, mask = pad_device_data(fed)
        hw, ch = fed.X_test.shape[1:3], fed.X_test.shape[3]

        # --- IKC: mini model on 1x10x10 crops
        t0 = time.perf_counter()
        mini = cnn.mini_init(key)
        crop = jax.vmap(cnn.mini_preprocess)(
            X[:, :, :, :, :1], jax.random.split(key, fed.n_devices))
        labels_i, _ = run_device_clustering(
            key, cnn.mini_apply, mini, crop, y, mask, 10, sp.L, 0.01,
            use_kernel=use_kernel)
        wall_i = time.perf_counter() - t0
        ari_i = adjusted_rand_index(labels_i, fed.majority_class)
        full_probe = cnn.cnn_init(key, hw, ch)
        d_i, e_i = clustering_cost(
            sp, pop, tree_bytes(mini) * 8,
            compute_scale=tree_bytes(mini) / tree_bytes(full_probe))

        # --- VKC: full model on full images
        t0 = time.perf_counter()
        full = cnn.cnn_init(key, hw, ch)
        labels_v, _ = run_device_clustering(
            key, cnn.cnn_apply, full, X, y, mask, 10, sp.L, 0.01,
            use_kernel=use_kernel)
        wall_v = time.perf_counter() - t0
        ari_v = adjusted_rand_index(labels_v, fed.majority_class)
        d_v, e_v = clustering_cost(sp, pop, tree_bytes(full) * 8)

        if dataset == "fmnist_syn":
            emit("table2/ikc", wall_i * 1e6,
                 f"delay_s={d_i:.1f};energy_j={e_i:.1f};ari={ari_i:.2f}")
        emit(f"table2/vkc_{dataset}", wall_v * 1e6,
             f"delay_s={d_v:.1f};energy_j={e_v:.1f};ari={ari_v:.2f}")
        rows.append((dataset, d_i, e_i, ari_i, d_v, e_v, ari_v))

    # paper claim: IKC delay/energy << VKC, both ARI = 1.0
    ok = all(d_i < 0.2 * d_v and e_i < 0.2 * e_v for _, d_i, e_i, _, d_v, e_v, _ in rows)
    emit("table2/claim_ikc_cheaper", 0.0, f"pass={ok}")


if __name__ == "__main__":
    run()
