"""Shared benchmark utilities + the reduced-scale world used by the paper
experiments (CPU container: scales recorded in EXPERIMENTS.md; relative
orderings are what we validate against the paper)."""
from __future__ import annotations

import time
from typing import Callable


from repro.core.cost_model import SystemParams, sample_population
from repro.data import make_dataset, partition_noniid

# Reduced-scale defaults (paper: N=100, M=5, D_n in [400,700], 5 repeats)
N_DEVICES = 40
N_EDGES = 5
SIZE_RANGE = (50, 90)
REPEATS = 2


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # us


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def make_world(dataset: str = "fmnist_syn", seed: int = 0,
               n_devices: int = N_DEVICES):
    sp = SystemParams(n_devices=n_devices, n_edges=N_EDGES,
                      d_range=SIZE_RANGE)
    pop = sample_population(sp, seed=seed)
    X, y, Xt, yt = make_dataset(dataset, n_train=6000, n_test=1000, seed=seed)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                           size_range=SIZE_RANGE, seed=seed)
    return sp, pop, fed
