"""Micro-benchmark: sequential per-edge round vs the fused round engine.

Measures, at the acceptance scale (M=10 edges, H=50 devices, CPU):
  * the seed's sequential path — M separate ``allocate`` jit calls with
    per-edge host round-trips, then ``round_cost`` and Algorithm-1
    training as separate dispatches;
  * the fused ``round_step`` — one jitted program for the whole round;
  * the allocation stage alone (per-edge loop vs ``allocate_all_edges``).

Emits CSV lines (benchmarks.common.emit) and writes
``BENCH_round_engine.json`` so future PRs can track the perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_round_engine
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core import resource as ra
from repro.core.framework import round_step
from repro.core.hfl import hfl_global_iteration

M_EDGES = 10
H_DEVICES = 50
ALLOC_STEPS = 300
REPEAT = 5


def _linear_apply(params, X):
    return X.reshape(X.shape[0], -1) @ params["w"]


def _world(seed: int = 0):
    sp = cm.SystemParams(n_devices=H_DEVICES, n_edges=M_EDGES)
    pop = cm.sample_population(sp, seed=seed)
    rng = np.random.default_rng(seed)
    sched = np.arange(H_DEVICES)
    assign = rng.integers(0, M_EDGES, H_DEVICES)
    Dmax = 8
    X = jnp.asarray(rng.normal(0, 1, (H_DEVICES, Dmax, 2, 2, 1))
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, (H_DEVICES, Dmax)).astype(np.int32))
    mask = jnp.ones((H_DEVICES, Dmax), jnp.float32)
    w0 = {"w": jnp.asarray(rng.normal(0, 0.1, (4, 3)).astype(np.float32))}
    return sp, pop, sched, assign, X, y, mask, w0


def sequential_alloc(sp, pop, sched, assign):
    """Seed-style per-edge loop with host round-trips."""
    H = len(sched)
    b = np.zeros(H)
    f = np.zeros(H)
    for m in range(pop.n_edges):
        mask = jnp.asarray(assign == m)
        res = ra.allocate(sp, pop.u[sched], pop.D[sched], pop.p[sched],
                          pop.g[sched, m], pop.B_m[m], mask,
                          steps=ALLOC_STEPS)
        sel = assign == m
        b[sel] = np.asarray(res.b)[sel]
        f[sel] = np.asarray(res.f)[sel]
    return b, f


def sequential_round(sp, pop, sched, assign, X, y, mask, w0):
    b, f = sequential_alloc(sp, pop, sched, assign)
    T_i, E_i, _, _ = cm.round_cost(sp, pop, jnp.asarray(sched),
                                   jnp.asarray(assign), jnp.asarray(b),
                                   jnp.asarray(f))
    w = hfl_global_iteration(_linear_apply, w0, X, y, mask, pop.D[sched],
                             jnp.asarray(assign), M=pop.n_edges, L=sp.L,
                             Q=sp.Q, lr=0.05)
    jax.block_until_ready((w, T_i, E_i))
    return float(T_i), float(E_i)


def fused_round(sp, pop, sched, assign, X, y, mask, w0):
    w, (T_i, E_i, _, _, _, _) = round_step(
        _linear_apply, sp, w0, pop.u[sched], pop.D[sched], pop.p[sched],
        pop.g[sched], pop.g_cloud, pop.B_m, X, y, mask, pop.D[sched],
        jnp.asarray(assign), 0.05, M=pop.n_edges, L=sp.L, Q=sp.Q,
        alloc_steps=ALLOC_STEPS)
    jax.block_until_ready((w, T_i, E_i))
    return float(T_i), float(E_i)


def _time(fn, *args, repeat: int = REPEAT):
    fn(*args)                                        # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / repeat


def run(out_json: str = "BENCH_round_engine.json"):
    sp, pop, sched, assign, X, y, mask, w0 = _world()

    # --- allocation stage only
    _, t_seq_alloc = _time(lambda: sequential_alloc(sp, pop, sched, assign))
    _, t_fus_alloc = _time(lambda: jax.block_until_ready(
        ra.allocate_all_edges(sp, pop, sched, assign, steps=ALLOC_STEPS)))

    # --- full round
    (T_seq, E_seq), t_seq_round = _time(
        lambda: sequential_round(sp, pop, sched, assign, X, y, mask, w0))
    (T_fus, E_fus), t_fus_round = _time(
        lambda: fused_round(sp, pop, sched, assign, X, y, mask, w0))

    assert abs(T_seq - T_fus) / T_seq < 1e-4, (T_seq, T_fus)
    assert abs(E_seq - E_fus) / E_seq < 1e-4, (E_seq, E_fus)

    result = {
        "M": M_EDGES, "H": H_DEVICES, "alloc_steps": ALLOC_STEPS,
        "repeat": REPEAT,
        "sequential_alloc_ms": t_seq_alloc * 1e3,
        "fused_alloc_ms": t_fus_alloc * 1e3,
        "alloc_speedup": t_seq_alloc / t_fus_alloc,
        "sequential_round_ms": t_seq_round * 1e3,
        "fused_round_ms": t_fus_round * 1e3,
        "round_speedup": t_seq_round / t_fus_round,
        "fused_allocations_per_s": M_EDGES / t_fus_alloc,
    }
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)

    emit("round_engine/alloc_sequential", t_seq_alloc * 1e6,
         f"M={M_EDGES};H={H_DEVICES}")
    emit("round_engine/alloc_fused", t_fus_alloc * 1e6,
         f"speedup={result['alloc_speedup']:.1f}x;"
         f"allocs_per_s={result['fused_allocations_per_s']:.0f}")
    emit("round_engine/round_sequential", t_seq_round * 1e6,
         f"T_i={T_seq:.2f};E_i={E_seq:.2f}")
    emit("round_engine/round_fused", t_fus_round * 1e6,
         f"speedup={result['round_speedup']:.1f}x")
    emit("round_engine/claim_fused_3x", 0.0,
         f"pass={result['round_speedup'] >= 3.0};"
         f"round={result['round_speedup']:.1f}x;"
         f"alloc={result['alloc_speedup']:.1f}x")
    return result


if __name__ == "__main__":
    run()
