"""Micro-benchmark: sequential per-edge round vs the fused round engine.

Measures, at the acceptance scale (M=10 edges, H=50 devices, CPU):
  * the seed's sequential path — M separate ``allocate`` jit calls with
    per-edge host round-trips, then ``round_cost`` and Algorithm-1
    training as separate dispatches;
  * the fused ``round_step`` — one jitted program for the whole round;
  * the allocation stage alone (per-edge loop vs ``allocate_all_edges``).

Emits CSV lines (benchmarks.common.emit) and writes
``BENCH_round_engine.json`` so future PRs can track the perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_round_engine [--smoke]

``--smoke`` runs tiny shapes and only asserts the benchmark runs
end-to-end and emits valid JSON (CI guard, no timing claims).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core import resource as ra
from repro.core.framework import round_step
from repro.core.hfl import hfl_global_iteration

M_EDGES = 10
H_DEVICES = 50
ALLOC_STEPS = 300
REPEAT = 5


def _linear_apply(params, X):
    return X.reshape(X.shape[0], -1) @ params["w"]


def _world(seed: int = 0, m_edges: int = M_EDGES,
           h_devices: int = H_DEVICES):
    sp = cm.SystemParams(n_devices=h_devices, n_edges=m_edges)
    pop = cm.sample_population(sp, seed=seed)
    rng = np.random.default_rng(seed)
    sched = np.arange(h_devices)
    assign = rng.integers(0, m_edges, h_devices)
    Dmax = 8
    X = jnp.asarray(rng.normal(0, 1, (h_devices, Dmax, 2, 2, 1))
                    .astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, (h_devices, Dmax)).astype(np.int32))
    mask = jnp.ones((h_devices, Dmax), jnp.float32)
    w0 = {"w": jnp.asarray(rng.normal(0, 0.1, (4, 3)).astype(np.float32))}
    return sp, pop, sched, assign, X, y, mask, w0


def sequential_alloc(sp, pop, sched, assign, alloc_steps: int = ALLOC_STEPS):
    """Seed-style per-edge loop with host round-trips."""
    H = len(sched)
    b = np.zeros(H)
    f = np.zeros(H)
    for m in range(pop.n_edges):
        mask = jnp.asarray(assign == m)
        res = ra.allocate(sp, pop.u[sched], pop.D[sched], pop.p[sched],
                          pop.g[sched, m], pop.B_m[m], mask,
                          steps=alloc_steps)
        sel = assign == m
        b[sel] = np.asarray(res.b)[sel]
        f[sel] = np.asarray(res.f)[sel]
    return b, f


def sequential_round(sp, pop, sched, assign, X, y, mask, w0,
                     alloc_steps: int = ALLOC_STEPS):
    b, f = sequential_alloc(sp, pop, sched, assign, alloc_steps)
    T_i, E_i, _, _ = cm.round_cost(sp, pop, jnp.asarray(sched),
                                   jnp.asarray(assign), jnp.asarray(b),
                                   jnp.asarray(f))
    w = hfl_global_iteration(_linear_apply, w0, X, y, mask, pop.D[sched],
                             jnp.asarray(assign), M=pop.n_edges, L=sp.L,
                             Q=sp.Q, lr=0.05)
    jax.block_until_ready((w, T_i, E_i))
    return float(T_i), float(E_i)


def fused_round(sp, pop, sched, assign, X, y, mask, w0,
                alloc_steps: int = ALLOC_STEPS):
    w, (T_i, E_i, _, _, _, _) = round_step(
        _linear_apply, sp, w0, pop.u[sched], pop.D[sched], pop.p[sched],
        pop.g[sched], pop.g_cloud, pop.B_m, X, y, mask, pop.D[sched],
        jnp.asarray(assign), 0.05, M=pop.n_edges, L=sp.L, Q=sp.Q,
        alloc_steps=alloc_steps)
    jax.block_until_ready((w, T_i, E_i))
    return float(T_i), float(E_i)


def _time(fn, *args, repeat: int = REPEAT):
    fn(*args)                                        # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / repeat


def run(out_json: str = "BENCH_round_engine.json", m_edges: int = M_EDGES,
        h_devices: int = H_DEVICES, alloc_steps: int = ALLOC_STEPS,
        repeat: int = REPEAT, check_speedup: bool = True):
    sp, pop, sched, assign, X, y, mask, w0 = _world(
        m_edges=m_edges, h_devices=h_devices)

    # --- allocation stage only
    _, t_seq_alloc = _time(
        lambda: sequential_alloc(sp, pop, sched, assign, alloc_steps),
        repeat=repeat)
    _, t_fus_alloc = _time(lambda: jax.block_until_ready(
        ra.allocate_all_edges(sp, pop, sched, assign, steps=alloc_steps)),
        repeat=repeat)

    # --- full round
    (T_seq, E_seq), t_seq_round = _time(
        lambda: sequential_round(sp, pop, sched, assign, X, y, mask, w0,
                                 alloc_steps), repeat=repeat)
    (T_fus, E_fus), t_fus_round = _time(
        lambda: fused_round(sp, pop, sched, assign, X, y, mask, w0,
                            alloc_steps), repeat=repeat)

    assert abs(T_seq - T_fus) / T_seq < 1e-4, (T_seq, T_fus)
    assert abs(E_seq - E_fus) / E_seq < 1e-4, (E_seq, E_fus)

    result = {
        "M": m_edges, "H": h_devices, "alloc_steps": alloc_steps,
        "repeat": repeat,
        "sequential_alloc_ms": t_seq_alloc * 1e3,
        "fused_alloc_ms": t_fus_alloc * 1e3,
        "alloc_speedup": t_seq_alloc / t_fus_alloc,
        "sequential_round_ms": t_seq_round * 1e3,
        "fused_round_ms": t_fus_round * 1e3,
        "round_speedup": t_seq_round / t_fus_round,
        "fused_allocations_per_s": m_edges / t_fus_alloc,
    }
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)

    emit("round_engine/alloc_sequential", t_seq_alloc * 1e6,
         f"M={m_edges};H={h_devices}")
    emit("round_engine/alloc_fused", t_fus_alloc * 1e6,
         f"speedup={result['alloc_speedup']:.1f}x;"
         f"allocs_per_s={result['fused_allocations_per_s']:.0f}")
    emit("round_engine/round_sequential", t_seq_round * 1e6,
         f"T_i={T_seq:.2f};E_i={E_seq:.2f}")
    emit("round_engine/round_fused", t_fus_round * 1e6,
         f"speedup={result['round_speedup']:.1f}x")
    if check_speedup:
        emit("round_engine/claim_fused_3x", 0.0,
             f"pass={result['round_speedup'] >= 3.0};"
             f"round={result['round_speedup']:.1f}x;"
             f"alloc={result['alloc_speedup']:.1f}x")
    return result


def run_smoke(out_json: str = "results/BENCH_round_engine_smoke.json"):
    """Tiny-shape CI guard: runs end-to-end, validates the emitted JSON."""
    result = run(out_json=out_json, m_edges=3, h_devices=8, alloc_steps=25,
                 repeat=1, check_speedup=False)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["fused_round_ms"] > 0 and loaded["M"] == 3
    emit("round_engine/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
