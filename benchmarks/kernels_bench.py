"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness +
call overhead; MXU-aligned block shapes are the TPU-relevant artifact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.hier_agg.ops import weighted_aggregate
from repro.kernels.kmeans_dist.ops import pairwise_sq_dists

KEY = jax.random.PRNGKey(0)


def run():
    # kmeans distance: IKC clustering shape (100 devices x mini-model dims)
    x = jax.random.normal(KEY, (100, 2560))
    c = jax.random.normal(KEY, (10, 2560))
    out, us = timed(lambda: jax.block_until_ready(
        pairwise_sq_dists(x, c, interpret=True)))
    emit("kernels/kmeans_dist_100x2560x10", us, "interpret=True")

    # hier agg: edge aggregation of 50 device CNNs (114k params)
    w = jax.random.uniform(KEY, (5, 50))
    w = w / w.sum(1, keepdims=True)
    d = jax.random.normal(KEY, (50, 114383))
    out, us = timed(lambda: jax.block_until_ready(
        weighted_aggregate(w, d, interpret=True)))
    emit("kernels/hier_agg_5x50x114k", us, "interpret=True")

    # flash attention: one GQA block
    q = jax.random.normal(KEY, (1, 256, 8, 64), jnp.bfloat16)
    k = jax.random.normal(KEY, (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(KEY, (1, 256, 2, 64), jnp.bfloat16)
    out, us = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)))
    emit("kernels/flash_attn_b1s256h8kv2", us, "interpret=True;causal")


if __name__ == "__main__":
    run()
