"""Micro-benchmark: device-sharded sweep lanes vs the single-device vmap.

Measures the fused sweep round at S ∈ {8, 32, 128} seed lanes in four
engine variants — {single-device, lane-sharded} x {whole-axis vmap,
``lane_chunk=1`` cache-blocked} — and tracks lanes/sec in
``BENCH_sweep_shard.json``. The workload is the allocation-heavy sweep
profile (M=10 edges, H=8 cohort, 500 solver steps, minimal local
training): the regime where the single-device program is serialized
(the convex-solver loop of tiny ops runs single-threaded on CPU) and
lane parallelism has real headroom; conv-heavy rounds are
DRAM-bandwidth-bound on CPU and gain little from extra *emulated*
devices.

The headline ``speedup_vs_single`` compares the best sharded variant
against the shipped PR-1..4 baseline (single-device whole-axis vmap,
what ``SweepRunner`` ran before this PR) — 2.24x at S=128 on the
committed 2-core run. ``speedup_vs_best_single`` decomposes it: the
chunked execution alone (available to both paths via ``lane_chunk``)
buys ~1.8x of that on CPU by keeping each chunk's working set
cache-resident, and device-parallelism the rest (~1.3x) — bounded by
the host's cores under emulation (all 8 devices share them), by the
device count on real hardware.

Because ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be
set before jax import, the measurement runs in a spawned child process
(``--child``); the parent validates the JSON and emits the CSV lines.
All variants are measured inside the same 8-device child (forcing the
device count shifts single-device round time by <10%, measured).

    PYTHONPATH=src python -m benchmarks.bench_sweep_shard [--smoke]

``--smoke`` spawns a tiny 2-device child and only asserts the benchmark
runs end-to-end and emits valid JSON (CI guard, no timing claims).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LANES = (8, 32, 128)
N_EMU_DEVICES = 8
ALLOC_STEPS = 500
M_EDGES = 10
N_DEVICES = 40
H_COHORT = 8
ROUNDS = 5


# --------------------------------------------------------------- child

def _measure(lanes, n_emu, *, n_devices, m_edges, h_cohort, alloc_steps,
             rounds, n_train, n_test):
    """Runs inside the forced-device-count child: time the fused sweep
    round single-device vs sharded at each lane count."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cost_model import SystemParams, sample_population
    from repro.core.sweep import (SweepRunner, sweep_round,
                                  sweep_round_sharded)
    from repro.data import make_dataset, partition_noniid

    assert len(jax.devices()) == n_emu, (
        f"child expected {n_emu} devices, got {len(jax.devices())}")
    sp = SystemParams(n_devices=n_devices, n_edges=m_edges, L=1, Q=1,
                      d_range=(1, 2))
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                n_test=n_test, seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                          size_range=(1, 2), seed=0)

    out = {"config": {"M": m_edges, "N": n_devices, "H": h_cohort,
                      "alloc_steps": alloc_steps, "rounds": rounds,
                      "emulated_devices": n_emu,
                      "host_cores": os.cpu_count(),
                      "mode": "cpu-emulation"},
           "lanes": {}}
    # four engine variants per lane count: {single, sharded} x
    # {vmap, chunked}. "single" (whole-axis vmap on one device) is the
    # PR-1..4 shipped baseline; "chunked" is the lane_chunk=1
    # cache-blocked execution — measured separately on BOTH paths so the
    # headline sharded win decomposes honestly into its cache-blocking
    # and device-parallel parts.
    variants = (("single", False, None), ("single_chunked", False, 1),
                ("shard", True, None), ("shard_chunked", True, 1))
    for S in lanes:
        row = {}
        for key, shard, chunk in variants:
            runner = SweepRunner(sp, [(pop, fed)] * S, lr=0.02,
                                 alloc_steps=alloc_steps, model_seed=0,
                                 shard=shard, lane_chunk=chunk)
            spp = dataclasses.replace(sp,
                                      model_bits=float(runner.model_bits))
            n = runner.S_pad
            sched = jnp.asarray(np.stack([np.arange(h_cohort)] * n))
            assign = jnp.asarray(
                np.stack([np.arange(h_cohort) % m_edges] * n))
            done = np.zeros(n, bool)
            done[S:] = True
            kw = dict(M=m_edges, L=1, Q=1, alloc_steps=alloc_steps,
                      lane_chunk=chunk, done_b=jnp.asarray(done))
            fn = sweep_round
            if shard:
                fn, kw["mesh"] = sweep_round_sharded, runner.mesh

            def call():
                _, (T, _) = fn(runner.apply_fn, spp, runner.params0,
                               runner.u_b, runner.D_b, runner.p_b,
                               runner.g_b, runner.g_cloud_b, runner.B_m_b,
                               runner.X_b, runner.y_b, runner.mask_b,
                               runner.D_b, sched, assign, 0.02, **kw)
                jax.block_until_ready(T)

            call()                                    # warmup / compile
            # min over rounds: on an oversubscribed emulation host the
            # mean is noise-dominated (±30% run-to-run, measured); the
            # per-path floor is the stable engine number.
            times = []
            for _ in range(rounds):
                t0 = time.perf_counter()
                call()
                times.append(time.perf_counter() - t0)
            dt = min(times)
            row[f"{key}_round_ms"] = dt * 1e3
            row[f"{key}_round_mean_ms"] = sum(times) / len(times) * 1e3
            row[f"{key}_lanes_per_s"] = S / dt
        best_shard = max(row["shard_lanes_per_s"],
                         row["shard_chunked_lanes_per_s"])
        best_single = max(row["single_lanes_per_s"],
                          row["single_chunked_lanes_per_s"])
        row["speedup_vs_single"] = best_shard / row["single_lanes_per_s"]
        row["speedup_vs_best_single"] = best_shard / best_single
        out["lanes"][str(S)] = row
    # On emulated CPU devices every program shares the host cores, so
    # the sharded-vs-best-single gain is bounded by host_cores, not by
    # the device count: that decomposed metric gates at a fraction of
    # the core-count ceiling; the headline vs the shipped single-device
    # vmap engine gates at the full 2x.
    cores = os.cpu_count() or 1
    out["best_single_speedup_gate"] = min(2.0, 0.6 * cores)
    return out


def _child_main(args):
    cfg = json.loads(args.config)
    result = _measure(tuple(cfg.pop("lanes")), cfg.pop("n_emu"), **cfg)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=1)


# -------------------------------------------------------------- parent

def _spawn(cfg: dict, n_emu: int) -> dict:
    from repro.utils import forced_device_env

    env = forced_device_env(
        n_emu, pythonpath=(os.path.join(REPO_ROOT, "src"), REPO_ROOT))
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
        out_path = tf.name
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_sweep_shard",
             "--child", "--out", out_path,
             "--config", json.dumps({**cfg, "n_emu": n_emu})],
            env=env, cwd=REPO_ROOT, capture_output=True, text=True,
            timeout=3600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sweep-shard child failed:\n{proc.stdout}\n{proc.stderr}")
        with open(out_path) as fh:
            return json.load(fh)
    finally:
        os.unlink(out_path)


def run(out_json: str = "BENCH_sweep_shard.json", lanes=LANES,
        n_emu: int = N_EMU_DEVICES, rounds: int = ROUNDS,
        check_claims: bool = True):
    from benchmarks.common import emit

    result = _spawn(dict(lanes=list(lanes), n_devices=N_DEVICES,
                         m_edges=M_EDGES, h_cohort=H_COHORT,
                         alloc_steps=ALLOC_STEPS, rounds=rounds,
                         n_train=120, n_test=20), n_emu)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)

    for S, row in result["lanes"].items():
        emit(f"sweep_shard/S{S}_single", row["single_round_ms"] * 1e3,
             f"lanes_per_s={row['single_lanes_per_s']:.1f};"
             f"chunked={row['single_chunked_lanes_per_s']:.1f}")
        emit(f"sweep_shard/S{S}_shard", row["shard_round_ms"] * 1e3,
             f"lanes_per_s={row['shard_lanes_per_s']:.1f};"
             f"chunked={row['shard_chunked_lanes_per_s']:.1f};"
             f"speedup={row['speedup_vs_single']:.2f}x;"
             f"vs_best_single={row['speedup_vs_best_single']:.2f}x")
    if check_claims:
        hi = result["lanes"][str(max(int(k) for k in result["lanes"]))]
        sp = hi["speedup_vs_single"]
        sp_dec = hi["speedup_vs_best_single"]
        gate = result["best_single_speedup_gate"]
        cores = result["config"]["host_cores"]
        emit("sweep_shard/claim_shard_2x", 0.0,
             f"pass={sp >= 2.0};speedup_vs_single_vmap={sp:.2f}x")
        emit("sweep_shard/claim_shard_vs_best_single", 0.0,
             f"pass={sp_dec >= gate};speedup={sp_dec:.2f}x;"
             f"gate={gate:.2f}x;host_cores={cores}")
    return result


def run_smoke(out_json: str = "results/BENCH_sweep_shard_smoke.json"):
    """Tiny-shape CI guard: 2 emulated devices, asserts the sharded and
    single paths both run end-to-end and the JSON is well-formed."""
    from benchmarks.common import emit

    result = _spawn(dict(lanes=[2, 4], n_devices=8, m_edges=2, h_cohort=4,
                         alloc_steps=25, rounds=1, n_train=60, n_test=20),
                    2)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["config"]["emulated_devices"] == 2
    assert all(row["shard_round_ms"] > 0 and row["single_round_ms"] > 0
               for row in loaded["lanes"].values())
    emit("sweep_shard/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", help=argparse.SUPPRESS)
    ap.add_argument("--config", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        _child_main(args)
    elif args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
