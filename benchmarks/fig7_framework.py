"""Fig. 7: full framework (Algorithm 6) — accuracy, objective (15), T, E,
message volume vs cohort size H (reduced scale)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, make_world
from repro.core.framework import FrameworkConfig, HFLFramework


def run(h_values=(10, 20, 40), target_acc: float = 0.62,
        max_iters: int = 12, out_json="results/fig7.json"):
    summary = {}
    for H in h_values:
        sp, pop, fed = make_world("fmnist_syn", seed=0)
        cfg = FrameworkConfig(scheduler="ikc" if H < fed.n_devices else "fedavg",
                              assigner="geo", H=H, K=10,
                              target_acc=target_acc, max_iters=max_iters,
                              alloc_steps=100, seed=0)
        t0 = time.perf_counter()
        fw = HFLFramework(sp, pop, fed, cfg)
        s = fw.run(verbose=False)
        wall = time.perf_counter() - t0
        summary[H] = s
        emit(f"fig7/H{H}", wall * 1e6,
             f"iters={s['iters']};acc={s['final_acc']:.3f};"
             f"T={s['T']:.0f};E={s['E']:.0f};obj={s['objective']:.0f};"
             f"msg_per_round_MB={s['msg_bits_per_round']/8e6:.1f}")
    os.makedirs("results", exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({str(k): {kk: vv for kk, vv in v.items() if kk != "history"}
                   for k, v in summary.items()}, f, indent=1)
    # paper claim: scheduling a fraction (here H=20 of 40) yields lower
    # objective than full participation (H=40)
    hs = sorted(summary)
    if len(hs) >= 2:
        frac, full = summary[hs[len(hs) // 2]], summary[hs[-1]]
        emit("fig7/claim_partial_cheaper", 0.0,
             f"pass={frac['objective'] < full['objective']};"
             f"partial_obj={frac['objective']:.0f};"
             f"full_obj={full['objective']:.0f}")
        # per-round message volume scales with H
        emit("fig7/claim_msgs_scale_with_H", 0.0,
             f"pass={summary[hs[0]]['msg_bits_per_round'] < summary[hs[-1]]['msg_bits_per_round']}")


if __name__ == "__main__":
    run()
