"""Fig. 7: full framework (Algorithm 6) — accuracy, objective (15), T, E,
message volume vs cohort size H (reduced scale).

Each H cell drives the fused batched round engine (``SweepRunner`` over
one lane: IKC scheduling, geographic assignment, vmapped all-edges
resource allocation, Algorithm-1 training fused into one jitted round)
instead of re-running the per-edge ``HFLFramework`` loop. Pass
``assign="hfel"`` to re-assign every round with the batched K-candidate
HFEL search instead of the geographic baseline.
"""
from __future__ import annotations

import json
import os
import time


from benchmarks.common import emit, make_world
from repro.core.sweep import SweepRunner, build_scheduler


def run(h_values=(10, 20, 40), target_acc: float = 0.62,
        max_iters: int = 12, out_json="results/fig7.json",
        assign: str = "geo", shard: bool = False):
    sp, pop, fed = make_world("fmnist_syn", seed=0)
    runner = SweepRunner(sp, [(pop, fed)], lr=0.01, alloc_steps=100,
                         model_seed=0, shard=shard)
    summary = {}
    for H in h_values:
        sched_name = "ikc" if H < fed.n_devices else "fedavg"
        t0 = time.perf_counter()
        sched, clustering = build_scheduler(sched_name, fed, sp, H, K=10,
                                            lr=0.01, seed=0, pop=pop)
        out = runner.run([sched], n_rounds=max_iters, assign=assign,
                         seeds=[0], target_acc=target_acc)
        wall = time.perf_counter() - t0
        it = int(out["iters"][0])
        T = float(out["T_i"][0, :it].sum())
        E = float(out["E_i"][0, :it].sum())
        s = {"iters": it, "final_acc": float(out["acc"][0, it - 1]),
             "T": T, "E": E, "objective": E + sp.lam * T,
             "msg_bits_per_round": out["msg_bits_per_round"],
             "total_msg_bits": out["msg_bits_per_round"] * it,
             "clustering": clustering}
        summary[H] = s
        emit(f"fig7/H{H}", wall * 1e6,
             f"iters={s['iters']};acc={s['final_acc']:.3f};"
             f"T={s['T']:.0f};E={s['E']:.0f};obj={s['objective']:.0f};"
             f"msg_per_round_MB={s['msg_bits_per_round']/8e6:.1f}")
    os.makedirs("results", exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({str(k): v for k, v in summary.items()}, f, indent=1)
    # paper claim: scheduling a fraction (here H=20 of 40) yields lower
    # objective than full participation (H=40)
    hs = sorted(summary)
    if len(hs) >= 2:
        frac, full = summary[hs[len(hs) // 2]], summary[hs[-1]]
        emit("fig7/claim_partial_cheaper", 0.0,
             f"pass={frac['objective'] < full['objective']};"
             f"partial_obj={frac['objective']:.0f};"
             f"full_obj={full['objective']:.0f}")
        # per-round message volume scales with H
        emit("fig7/claim_msgs_scale_with_H", 0.0,
             f"pass={summary[hs[0]]['msg_bits_per_round'] < summary[hs[-1]]['msg_bits_per_round']}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--assign", default="geo",
                    help="geo (default) | hfel | mod")
    ap.add_argument("--shard", action="store_true",
                    help="shard sweep lanes over the local devices "
                         "(set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N before launch for CPU emulation)")
    args = ap.parse_args()
    run(assign=args.assign, shard=args.shard)
