"""Micro-benchmark: serial vs batched D3QN episode engine (Alg. 5).

Times D3QN training episodes/sec under both engines at identical
per-episode workloads (same HFEL imitation budget, allocator steps and
minibatch size):

  * ``engine="serial"`` — one population, one HFEL target search, one
    ε-greedy pass and one optimizer step per episode;
  * ``engine="batched"`` — waves of E episodes: one
    ``sample_population_batch``, lockstep HFEL searches
    (``assign_batch``: all populations' candidate edges in ONE
    ``allocate_batch_warm`` dispatch per wave round), one jitted acting
    pass and one jitted ``lax.scan`` of E TD updates per wave.

Cases: the Fig.-5 training shape (M=5, H=20) and a paper-scale point
(M=10, H=50). Emits CSV lines (benchmarks.common.emit) and writes
``BENCH_drl_train.json`` so future PRs can track the perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_drl_train [--smoke]

``--smoke`` runs a tiny shape with a tiny budget and only asserts the
benchmark runs end-to-end and emits valid JSON (CI guard, no timing
claims).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import emit
from repro.core.cost_model import SystemParams
from repro.drl.train import D3QNTrainer

CASES = (
    # name, M, H, measured episodes (a multiple of WAVE_SIZE, so the
    # batched timing covers whole waves at the compiled shapes)
    ("fig5", 5, 20, 64),
    ("paper", 10, 50, 32),
)
WAVE_SIZE = 32
HFEL_TRANSFER = 40
HFEL_EXCHANGE = 80
ALLOC_STEPS = 60
MINIBATCH = 96
HIDDEN = 64


def _episodes_per_sec(engine: str, sp: SystemParams, H: int,
                      episodes: int, warmup: int, **kw) -> float:
    """Train ``warmup`` episodes (compile-bearing, untimed) then time
    ``episodes`` more; returns episodes/sec."""
    tr = D3QNTrainer(sp, H=H, engine=engine, seed=0, **kw)
    tr.train(max_episodes=warmup, verbose=False)
    t0 = time.perf_counter()
    tr.train(max_episodes=episodes, verbose=False)
    return episodes / (time.perf_counter() - t0)


def run(out_json: str = "BENCH_drl_train.json", cases=CASES,
        wave_size: int = WAVE_SIZE, hfel_transfer: int = HFEL_TRANSFER,
        hfel_exchange: int = HFEL_EXCHANGE, alloc_steps: int = ALLOC_STEPS,
        minibatch: int = MINIBATCH, hidden: int = HIDDEN,
        check_speedup: bool = True):
    results = {}
    for name, M, H, episodes in cases:
        sp = SystemParams(n_devices=H, n_edges=M, lam=1.0)
        kw = dict(hidden=hidden, hfel_transfer=hfel_transfer,
                  hfel_exchange=hfel_exchange, alloc_steps=alloc_steps,
                  minibatch=minibatch)
        # warmup covers buffer fill + every compiled shape (one full
        # wave warms acting, the update scan and the search rounds)
        warmup = max(wave_size, 2 * (minibatch // H) + 2)
        assert episodes % wave_size == 0, \
            "measured episodes must be whole waves"
        eps_ser = _episodes_per_sec("serial", sp, H, episodes, warmup,
                                    **kw)
        eps_bat = _episodes_per_sec("batched", sp, H, episodes, warmup,
                                    wave_size=wave_size, **kw)
        case = {
            "M": M, "H": H, "episodes": episodes,
            "serial_eps_per_s": eps_ser, "batched_eps_per_s": eps_bat,
            "speedup": eps_bat / eps_ser,
        }
        results[name] = case
        emit(f"drl_train/serial_{name}", 1e6 / eps_ser,
             f"M={M};H={H};budget={hfel_transfer}+{hfel_exchange};"
             f"eps_per_s={eps_ser:.2f}")
        emit(f"drl_train/batched_{name}", 1e6 / eps_bat,
             f"E={wave_size};speedup={case['speedup']:.1f}x;"
             f"eps_per_s={eps_bat:.2f}")

    payload = {
        "wave_size": wave_size, "hfel_transfer": hfel_transfer,
        "hfel_exchange": hfel_exchange, "alloc_steps": alloc_steps,
        "minibatch": minibatch, "hidden": hidden, "cases": results,
    }
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(payload, fh, indent=1)

    if check_speedup:
        fig5 = results["fig5"]
        emit("drl_train/claim_batched_3x", 0.0,
             f"pass={fig5['speedup'] >= 3.0};"
             f"speedup={fig5['speedup']:.1f}x")
    return payload


def run_smoke(out_json: str = "results/BENCH_drl_train_smoke.json"):
    """Tiny-shape CI guard: runs end-to-end, validates the emitted JSON."""
    result = run(out_json=out_json, cases=(("fig5", 3, 8, 4),),
                 wave_size=4, hfel_transfer=4, hfel_exchange=6,
                 alloc_steps=20, minibatch=16, hidden=16,
                 check_speedup=False)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["cases"]["fig5"]["serial_eps_per_s"] > 0
    assert loaded["cases"]["fig5"]["batched_eps_per_s"] > 0
    assert result["wave_size"] == 4
    emit("drl_train/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
