"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Emits ``name,us_per_call,derived`` CSV lines (stdout). Heavy suites run at
reduced scale by default (CPU container); EXPERIMENTS.md records the
scale factors and validates the paper's *relative* claims.
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table2|fig34|fig5|fig6|fig7|kernels|roofline|engine")
    ap.add_argument("--fast", action="store_true",
                    help="minimal iteration counts")
    args = ap.parse_args()

    print("name,us_per_call,derived", flush=True)
    t_all = time.time()

    def want(name):
        return args.only in (None, name)

    trained = None
    try:
        if want("kernels"):
            from benchmarks import kernels_bench
            kernels_bench.run()
        if want("table2"):
            from benchmarks import table2_clustering
            table2_clustering.run()
        if want("fig5"):
            from benchmarks import fig5_drl_curve
            trained = fig5_drl_curve.run(
                episodes=80 if args.fast else 400)
        if want("fig6"):
            from benchmarks import fig6_assignment
            fig6_assignment.run(trained_trainer=trained,
                                n_pops=4 if args.fast else 12)
        if want("fig34"):
            from benchmarks import fig34_convergence
            fig34_convergence.run(iters=4 if args.fast else 10,
                                  h_values=(10,) if args.fast else (10, 20))
        if want("fig7"):
            from benchmarks import fig7_framework
            fig7_framework.run(h_values=(10, 20) if args.fast else (10, 20, 40),
                               max_iters=4 if args.fast else 12)
        if want("roofline"):
            from benchmarks import roofline
            roofline.run()
        if want("engine"):
            from benchmarks import bench_round_engine
            bench_round_engine.run()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        print("benchmark_suite,0.0,FAILED", flush=True)
        raise
    print(f"benchmark_suite_total,{(time.time()-t_all)*1e6:.0f},ok",
          flush=True)


if __name__ == "__main__":
    main()
