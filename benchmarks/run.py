"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke|--perf] [--only NAME]

Emits ``name,us_per_call,derived`` CSV lines (stdout). Heavy suites run at
reduced scale by default (CPU container); EXPERIMENTS.md records the
scale factors and validates the paper's *relative* claims. ``--smoke``
restricts to the perf-tracking micro-benchmarks (engine / hfel /
hier_agg / drl_train) at their tiny CI shapes — the bench-smoke CI job runs exactly
that and uploads the ``results/*.json`` outputs as artifacts. ``--perf``
runs the same four at full scale but writes the JSON under
``results/`` (gitignored), so the weekly CI job's artifacts are always
freshly produced files, never the committed repo-root ``BENCH_*.json``.

Each sub-benchmark runs in its own try block: one failure prints a
``<name>,0.0,FAILED`` line and the remaining suites still run, but the
process exits non-zero so CI can gate on the harness. Per-suite wall
times are collected and, when ``$GITHUB_STEP_SUMMARY`` is set (any
GitHub Actions job), appended there as a markdown table.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def write_step_summary(rows, total_s: float, path: str | None = None) -> None:
    """Append the per-suite timings table to $GITHUB_STEP_SUMMARY (no-op
    outside GitHub Actions). rows: [(suite, seconds, status)]."""
    path = path or os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = ["## Benchmark suite timings", "",
             "| suite | wall time | status |",
             "|---|---:|---|"]
    for name, secs, status in rows:
        lines.append(f"| {name} | {secs:.1f} s | {status} |")
    lines += [f"| **total** | **{total_s:.1f} s** | |", ""]
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table2|fig34|fig5|fig6|fig7|kernels|roofline|"
                         "engine|hfel|hier_agg|drl_train")
    ap.add_argument("--fast", action="store_true",
                    help="minimal iteration counts")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: only the perf micro-benchmarks at "
                         "tiny shapes (JSON under results/)")
    ap.add_argument("--perf", action="store_true",
                    help="only the perf micro-benchmarks at full scale, "
                         "JSON written under results/ (fresh files for "
                         "CI artifacts — never the committed repo-root "
                         "BENCH_*.json)")
    args = ap.parse_args()

    state = {"trained": None}

    def run_kernels():
        from benchmarks import kernels_bench
        kernels_bench.run()

    def run_table2():
        from benchmarks import table2_clustering
        table2_clustering.run()

    def run_fig5():
        from benchmarks import fig5_drl_curve
        state["trained"] = fig5_drl_curve.run(
            episodes=80 if args.fast else 400)

    def run_fig6():
        from benchmarks import fig6_assignment
        fig6_assignment.run(trained_trainer=state["trained"],
                            n_pops=4 if args.fast else 12)

    def run_fig34():
        from benchmarks import fig34_convergence
        fig34_convergence.run(iters=4 if args.fast else 10,
                              h_values=(10,) if args.fast else (10, 20))

    def run_fig7():
        from benchmarks import fig7_framework
        fig7_framework.run(h_values=(10, 20) if args.fast else (10, 20, 40),
                           max_iters=4 if args.fast else 12)

    def run_roofline():
        from benchmarks import roofline
        roofline.run()

    def _perf_bench(mod, name):
        if args.smoke:
            mod.run_smoke()
        elif args.perf:
            mod.run(out_json=f"results/BENCH_{name}.json")
        else:
            mod.run()

    def run_engine():
        from benchmarks import bench_round_engine
        _perf_bench(bench_round_engine, "round_engine")

    def run_hfel():
        from benchmarks import bench_hfel_search
        _perf_bench(bench_hfel_search, "hfel_search")

    def run_hier_agg():
        from benchmarks import bench_hier_agg
        _perf_bench(bench_hier_agg, "hier_agg")

    def run_drl_train():
        from benchmarks import bench_drl_train
        _perf_bench(bench_drl_train, "drl_train")

    # fig6 reuses fig5's trained D3QN when both are selected, so order
    # matters: fig5 before fig6
    suites = [
        ("kernels", run_kernels),
        ("table2", run_table2),
        ("fig5", run_fig5),
        ("fig6", run_fig6),
        ("fig34", run_fig34),
        ("fig7", run_fig7),
        ("roofline", run_roofline),
        ("engine", run_engine),
        ("hfel", run_hfel),
        ("hier_agg", run_hier_agg),
        ("drl_train", run_drl_train),
    ]
    if args.smoke or args.perf:
        perf_names = ("engine", "hfel", "hier_agg", "drl_train")
        suites = [(n, fn) for n, fn in suites if n in perf_names]

    names = [n for n, _ in suites]
    if args.only is not None and args.only not in names:
        ap.error(f"--only must be one of {'|'.join(names)}")

    print("name,us_per_call,derived", flush=True)
    t_all = time.time()
    failed = []
    timings = []
    for name, fn in suites:
        if args.only not in (None, name):
            continue
        t0 = time.time()
        try:
            fn()
            timings.append((name, time.time() - t0, "ok"))
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},0.0,FAILED", flush=True)
            failed.append(name)
            timings.append((name, time.time() - t0, "FAILED"))
    total = time.time() - t_all
    status = f"failed={'|'.join(failed)}" if failed else "ok"
    print(f"benchmark_suite_total,{total * 1e6:.0f},{status}", flush=True)
    write_step_summary(timings, total)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
