"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Emits ``name,us_per_call,derived`` CSV lines (stdout). Heavy suites run at
reduced scale by default (CPU container); EXPERIMENTS.md records the
scale factors and validates the paper's *relative* claims.

Each sub-benchmark runs in its own try block: one failure prints a
``<name>,0.0,FAILED`` line and the remaining suites still run, but the
process exits non-zero so CI can gate on the harness.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table2|fig34|fig5|fig6|fig7|kernels|roofline|"
                         "engine|hfel")
    ap.add_argument("--fast", action="store_true",
                    help="minimal iteration counts")
    args = ap.parse_args()

    state = {"trained": None}

    def run_kernels():
        from benchmarks import kernels_bench
        kernels_bench.run()

    def run_table2():
        from benchmarks import table2_clustering
        table2_clustering.run()

    def run_fig5():
        from benchmarks import fig5_drl_curve
        state["trained"] = fig5_drl_curve.run(
            episodes=80 if args.fast else 400)

    def run_fig6():
        from benchmarks import fig6_assignment
        fig6_assignment.run(trained_trainer=state["trained"],
                            n_pops=4 if args.fast else 12)

    def run_fig34():
        from benchmarks import fig34_convergence
        fig34_convergence.run(iters=4 if args.fast else 10,
                              h_values=(10,) if args.fast else (10, 20))

    def run_fig7():
        from benchmarks import fig7_framework
        fig7_framework.run(h_values=(10, 20) if args.fast else (10, 20, 40),
                           max_iters=4 if args.fast else 12)

    def run_roofline():
        from benchmarks import roofline
        roofline.run()

    def run_engine():
        from benchmarks import bench_round_engine
        bench_round_engine.run()

    def run_hfel():
        from benchmarks import bench_hfel_search
        bench_hfel_search.run()

    # fig6 reuses fig5's trained D3QN when both are selected, so order
    # matters: fig5 before fig6
    suites = [
        ("kernels", run_kernels),
        ("table2", run_table2),
        ("fig5", run_fig5),
        ("fig6", run_fig6),
        ("fig34", run_fig34),
        ("fig7", run_fig7),
        ("roofline", run_roofline),
        ("engine", run_engine),
        ("hfel", run_hfel),
    ]

    names = [n for n, _ in suites]
    if args.only is not None and args.only not in names:
        ap.error(f"--only must be one of {'|'.join(names)}")

    print("name,us_per_call,derived", flush=True)
    t_all = time.time()
    failed = []
    for name, fn in suites:
        if args.only not in (None, name):
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},0.0,FAILED", flush=True)
            failed.append(name)
    status = f"failed={'|'.join(failed)}" if failed else "ok"
    print(f"benchmark_suite_total,{(time.time()-t_all)*1e6:.0f},{status}",
          flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
