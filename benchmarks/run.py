"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke|--perf] [--only NAME]

Emits ``name,us_per_call,derived`` CSV lines (stdout). Heavy suites run at
reduced scale by default (CPU container); EXPERIMENTS.md records the
scale factors and validates the paper's *relative* claims. ``--smoke``
restricts to the perf-tracking micro-benchmarks (engine / hfel /
hier_agg / drl_train / sweep_shard / sweep_fused / schedule_scale /
async_engine / comm_compress / model_zoo) at their tiny CI shapes — the
bench-smoke CI job runs exactly that and uploads the ``results/*.json``
outputs as artifacts.
``--perf`` runs the same ten at full scale but writes the JSON under
``results/`` (gitignored), so the weekly CI job's artifacts are always
freshly produced files, never the committed repo-root ``BENCH_*.json``.
``--check`` then compares the fresh smoke timings against the committed
``benchmarks/baselines/*.json`` and fails the run on a >2x slowdown of
any shared ``*_ms`` field (``$BENCH_CHECK_FACTOR`` overrides the
factor; the 5ms noise floor applies per field — sub-floor baselines are
gated against ``floor*factor`` rather than skipped). The full guard
contract is documented in ``benchmarks/README.md``.

Each sub-benchmark runs in its own try block: one failure prints a
``<name>,0.0,FAILED`` line and the remaining suites still run, but the
process exits non-zero so CI can gate on the harness. Per-suite wall
times are collected and, when ``$GITHUB_STEP_SUMMARY`` is set (any
GitHub Actions job), appended there as a markdown table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
import traceback

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")


def _perf_fields(obj, prefix=""):
    """Recursively collect comparable perf entries from a bench JSON.

    Returns {path: (value_ms_or_rate, kind)} with kind "time" for
    ``*_ms`` / ``*_s`` fields (normalised to ms; lower is better) and
    "rate" for ``*_per_s`` throughputs (higher is better). Walks nested
    dicts AND lists so every smoke baseline contributes fields (hfel
    emits ``*_s`` under cases, drl only ``*_eps_per_s``, hier_agg a list
    of sweep rows)."""
    out = {}
    if isinstance(obj, dict):
        items = obj.items()
    elif isinstance(obj, list):
        items = ((str(i), v) for i, v in enumerate(obj))
    else:
        return out
    for k, v in items:
        path = f"{prefix}{k}"
        if isinstance(v, (dict, list)):
            out.update(_perf_fields(v, prefix=f"{path}."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            if k.endswith("_per_s"):
                out[path] = (float(v), "rate")
            elif k.endswith("_ms"):
                out[path] = (float(v), "time")
            elif k.endswith("_s"):
                out[path] = (float(v) * 1e3, "time")
    return out


def check_regressions(results_dir: str = "results",
                      baseline_dir: str = BASELINE_DIR,
                      factor: float | None = None,
                      floor_ms: float = 5.0) -> list[str]:
    """Compare fresh smoke perf numbers against the committed baselines.

    For every baseline under ``benchmarks/baselines/``, the matching
    fresh file under ``results_dir`` must exist (a missing file means
    the results pipeline drifted — that IS a failure, not a skip) and
    each shared field must stay within ``factor``x of the baseline
    (default 2, override via $BENCH_CHECK_FACTOR): timing fields
    (``*_ms`` / ``*_s``) must not slow down past factor*x, throughput
    fields (``*_per_s``) must not drop below baseline/factor. The noise
    floor applies PER FIELD: a timing field is gated against
    ``max(baseline, floor_ms) * factor``, so sub-5ms baselines (pure
    dispatch overhead at smoke shapes) tolerate jitter up to
    ``floor_ms * factor`` but still fail on a real blow-up — the old
    behaviour of skipping them entirely let a 4ms -> 400ms regression
    through unreported. Comparing zero fields overall is also a failure
    (a vacuously green guard is a disabled guard). Returns the list of
    violation strings. Full contract: ``benchmarks/README.md``.
    """
    if factor is None:
        factor = float(os.environ.get("BENCH_CHECK_FACTOR", "2.0"))
    failures = []
    compared = 0
    for base_path in sorted(glob.glob(os.path.join(baseline_dir,
                                                   "BENCH_*.json"))):
        name = os.path.basename(base_path)
        fresh_path = os.path.join(results_dir, name)
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: fresh results file missing under "
                            f"{results_dir}/ (pipeline drift?)")
            continue
        with open(base_path) as fh:
            base = _perf_fields(json.load(fh))
        with open(fresh_path) as fh:
            fresh = _perf_fields(json.load(fh))
        for field, (base_v, kind) in sorted(base.items()):
            if field not in fresh or fresh[field][1] != kind:
                continue
            if kind == "rate" and base_v <= 0:
                continue
            compared += 1
            fresh_v = fresh[field][0]
            # per-field noise floor: sub-floor baselines are measured
            # against floor_ms*factor instead of being skipped, so
            # dispatch-overhead jitter passes but a real blow-up fails
            if kind == "time" and fresh_v > max(base_v, floor_ms) * factor:
                failures.append(
                    f"{name}:{field} {fresh_v:.1f}ms vs baseline "
                    f"{base_v:.1f}ms ({fresh_v / max(base_v, 1e-9):.2f}x, "
                    f"gate {max(base_v, floor_ms) * factor:.1f}ms)")
            elif kind == "rate" and fresh_v < base_v / factor:
                failures.append(
                    f"{name}:{field} {fresh_v:.2f}/s vs baseline "
                    f"{base_v:.2f}/s ({base_v / fresh_v:.2f}x drop > "
                    f"{factor:.1f}x)")
    if compared == 0:
        failures.append("no comparable fields between baselines and "
                        "fresh results — guard is vacuous")
    status = f"failures={len(failures)}" if failures else "ok"
    print(f"bench-check,{compared:.1f},{status}", flush=True)
    for f in failures:
        print(f"bench-check-REGRESSION,0.0,{f}", flush=True)
    return failures


def write_step_summary(rows, total_s: float, path: str | None = None) -> None:
    """Append the per-suite timings table to $GITHUB_STEP_SUMMARY (no-op
    outside GitHub Actions). rows: [(suite, seconds, status)]."""
    path = path or os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = ["## Benchmark suite timings", "",
             "| suite | wall time | status |",
             "|---|---:|---|"]
    for name, secs, status in rows:
        lines.append(f"| {name} | {secs:.1f} s | {status} |")
    lines += [f"| **total** | **{total_s:.1f} s** | |", ""]
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="table2|fig34|fig5|fig6|fig7|kernels|roofline|"
                         "engine|hfel|hier_agg|drl_train|sweep_shard|"
                         "sweep_fused|schedule_scale|async_engine|"
                         "comm_compress|model_zoo")
    ap.add_argument("--fast", action="store_true",
                    help="minimal iteration counts")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: only the perf micro-benchmarks at "
                         "tiny shapes (JSON under results/)")
    ap.add_argument("--perf", action="store_true",
                    help="only the perf micro-benchmarks at full scale, "
                         "JSON written under results/ (fresh files for "
                         "CI artifacts — never the committed repo-root "
                         "BENCH_*.json)")
    ap.add_argument("--check", action="store_true",
                    help="after the suites, compare results/*_smoke.json "
                         "timings against the committed "
                         "benchmarks/baselines/ and exit non-zero on a "
                         ">2x slowdown ($BENCH_CHECK_FACTOR overrides)")
    args = ap.parse_args()

    state = {"trained": None}

    def run_kernels():
        from benchmarks import kernels_bench
        kernels_bench.run()

    def run_table2():
        from benchmarks import table2_clustering
        table2_clustering.run()

    def run_fig5():
        from benchmarks import fig5_drl_curve
        state["trained"] = fig5_drl_curve.run(
            episodes=80 if args.fast else 400)

    def run_fig6():
        from benchmarks import fig6_assignment
        fig6_assignment.run(trained_trainer=state["trained"],
                            n_pops=4 if args.fast else 12)

    def run_fig34():
        from benchmarks import fig34_convergence
        fig34_convergence.run(iters=4 if args.fast else 10,
                              h_values=(10,) if args.fast else (10, 20))

    def run_fig7():
        from benchmarks import fig7_framework
        fig7_framework.run(h_values=(10, 20) if args.fast else (10, 20, 40),
                           max_iters=4 if args.fast else 12)

    def run_roofline():
        from benchmarks import roofline
        roofline.run()

    def _perf_bench(mod, name):
        if args.smoke:
            mod.run_smoke()
        elif args.perf:
            mod.run(out_json=f"results/BENCH_{name}.json")
        else:
            mod.run()

    def run_engine():
        from benchmarks import bench_round_engine
        _perf_bench(bench_round_engine, "round_engine")

    def run_hfel():
        from benchmarks import bench_hfel_search
        _perf_bench(bench_hfel_search, "hfel_search")

    def run_hier_agg():
        from benchmarks import bench_hier_agg
        _perf_bench(bench_hier_agg, "hier_agg")

    def run_drl_train():
        from benchmarks import bench_drl_train
        _perf_bench(bench_drl_train, "drl_train")

    def run_sweep_shard():
        from benchmarks import bench_sweep_shard
        _perf_bench(bench_sweep_shard, "sweep_shard")

    def run_sweep_fused():
        from benchmarks import bench_sweep_fused
        _perf_bench(bench_sweep_fused, "sweep_fused")

    def run_schedule_scale():
        from benchmarks import bench_schedule_scale
        _perf_bench(bench_schedule_scale, "schedule_scale")

    def run_async_engine():
        from benchmarks import bench_async_engine
        _perf_bench(bench_async_engine, "async_engine")

    def run_comm_compress():
        from benchmarks import bench_comm_compress
        _perf_bench(bench_comm_compress, "comm_compress")

    def run_model_zoo():
        from benchmarks import bench_model_zoo
        _perf_bench(bench_model_zoo, "model_zoo")

    # fig6 reuses fig5's trained D3QN when both are selected, so order
    # matters: fig5 before fig6
    suites = [
        ("kernels", run_kernels),
        ("table2", run_table2),
        ("fig5", run_fig5),
        ("fig6", run_fig6),
        ("fig34", run_fig34),
        ("fig7", run_fig7),
        ("roofline", run_roofline),
        ("engine", run_engine),
        ("hfel", run_hfel),
        ("hier_agg", run_hier_agg),
        ("drl_train", run_drl_train),
        ("sweep_shard", run_sweep_shard),
        ("sweep_fused", run_sweep_fused),
        ("schedule_scale", run_schedule_scale),
        ("async_engine", run_async_engine),
        ("comm_compress", run_comm_compress),
        ("model_zoo", run_model_zoo),
    ]
    if args.smoke or args.perf:
        perf_names = ("engine", "hfel", "hier_agg", "drl_train",
                      "sweep_shard", "sweep_fused", "schedule_scale",
                      "async_engine", "comm_compress", "model_zoo")
        suites = [(n, fn) for n, fn in suites if n in perf_names]

    names = [n for n, _ in suites]
    if args.only is not None and args.only not in names:
        ap.error(f"--only must be one of {'|'.join(names)}")

    print("name,us_per_call,derived", flush=True)
    t_all = time.time()
    failed = []
    timings = []
    for name, fn in suites:
        if args.only not in (None, name):
            continue
        t0 = time.time()
        try:
            fn()
            timings.append((name, time.time() - t0, "ok"))
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},0.0,FAILED", flush=True)
            failed.append(name)
            timings.append((name, time.time() - t0, "FAILED"))
    # the regression check runs BEFORE the status line / step summary so
    # a check-only failure is visible in both, not just the exit code
    if args.check:
        t0 = time.time()
        regressions = check_regressions()
        if regressions:
            failed.append("bench-check")
        timings.append(("bench-check", time.time() - t0,
                        "FAILED" if regressions else "ok"))
    total = time.time() - t_all
    status = f"failed={'|'.join(failed)}" if failed else "ok"
    print(f"benchmark_suite_total,{total * 1e6:.0f},{status}", flush=True)
    write_step_summary(timings, total)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
