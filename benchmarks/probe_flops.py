"""Exact per-step HLO totals via unrolled linear probes.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so the dry-run's raw `flops` undercounts the layer scan (nb
iterations) and the microbatch scan (mb iterations). We recover exact
totals from three SMALL probe compiles with the scans UNROLLED
(cfg.unroll_layers=True):

    f(nb) = E + nb * B   (probes at nb=1, nb=2 with mb=1: B = f21 - f11)
    total = f11 + (nb_full - 1) * B

Microbatching does NOT change FLOP/byte totals (it splits the same global
batch), so probes run at mb=1 with the full batch. One exception is
collective bytes: FSDP weight all-gathers repeat once per microbatch; we
add the analytic re-gather term (mb-1) * param_bytes(bf16)/TP to `coll`
and record it separately as `coll_regather`.

Run:  PYTHONPATH=src python -m benchmarks.probe_flops [--arch A] [--shape S]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config, variant_for_shape
from repro.launch import steps as S
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import super_block

METRICS = ("flops", "bytes", "coll")


def _measure(cfg, shape, mesh):
    """Compile one probe; return dict of per-device totals."""
    with mesh:
        if shape.kind == "train":
            step, opt = S.make_train_step(cfg, mesh)
            ps = S.params_struct(cfg, mesh)
            os_ = S.opt_state_struct(cfg, mesh, opt)
            batch = S.input_specs(cfg, shape, mesh)
            compiled = jax.jit(step).lower(ps, os_, batch).compile()
        elif shape.kind == "prefill":
            step = S.make_prefill_step(cfg, mesh)
            ps = S.params_struct(cfg, mesh)
            batch = S.input_specs(cfg, shape, mesh)
            compiled = jax.jit(step).lower(ps, batch).compile()
        else:
            step = S.make_serve_step(cfg, mesh)
            ps = S.params_struct(cfg, mesh)
            cache = S.cache_specs_struct(cfg, shape, mesh)
            ins = S.input_specs(cfg, shape, mesh)
            compiled = jax.jit(step).lower(ps, cache, ins["tokens"],
                                           ins["pos"]).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        coll = parse_collectives(compiled.as_text())
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(sum(v["bytes"] for v in coll.values()))}


def probe_pair(arch: str, shape_name: str) -> dict:
    mesh = make_production_mesh()
    shape = INPUT_SHAPES[shape_name]
    cfg_full = variant_for_shape(get_config(arch), shape)
    sb = super_block(cfg_full)
    nb_full = cfg_full.n_layers // sb
    mb_full = max(1, cfg_full.microbatches) if shape.kind == "train" else 1

    def probe(nb):
        c = dataclasses.replace(cfg_full, n_layers=sb * nb,
                                microbatches=1, unroll_layers=True)
        return _measure(c, shape, mesh)

    f11 = probe(1)
    f21 = probe(2)
    out = {"arch": arch, "shape": shape_name, "nb": nb_full, "mb": mb_full}
    for m in METRICS:
        Bv = f21[m] - f11[m]
        out[m] = f11[m] + (nb_full - 1) * Bv
        out[m + "_parts"] = {"E": f11[m] - Bv, "B": Bv}
    if shape.kind == "train" and mb_full > 1:
        # FSDP weight re-gather: each extra microbatch re-gathers the
        # bf16 weights (model-sharded slice) once per device
        regather = (mb_full - 1) * cfg_full.param_count() * 2 / 16
        out["coll_regather"] = regather
        out["coll"] += regather
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/probes.json")
    args = ap.parse_args()
    # cheapest-first so the table fills early (jamba's 16-layer unrolled
    # MoE+SSD probes are by far the slowest compiles)
    default_order = ["mamba2-2.7b", "chatglm3-6b", "musicgen-medium",
                     "mistral-nemo-12b", "internvl2-26b",
                     "llama4-scout-17b-a16e", "mistral-large-123b",
                     "llama3-405b", "qwen3-moe-235b-a22b",
                     "jamba-1.5-large-398b"]
    archs = [args.arch] if args.arch else default_order
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"]) for r in results if "error" not in r}
    for arch in archs:
        for shape in shapes:
            if (arch, shape) in done:
                continue
            t0 = time.time()
            try:
                rec = probe_pair(arch, shape)
                print(f"probe {arch} x {shape}: flops={rec['flops']:.3e} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "error": f"{type(e).__name__}: {e}"}
            results = [r for r in results
                       if (r["arch"], r["shape"]) != (arch, shape)]
            results.append(rec)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
