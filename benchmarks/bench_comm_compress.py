"""Micro-benchmark: uplink compression — accuracy vs bytes on the wire.

Sweeps the update codecs (``none`` / ``bf16_delta`` / ``int8`` /
``topk``) at the paper's 50% / 30% scheduling ratios through the fused
round engine (``HFLFramework`` with the plain ``fedavg`` scheduler, so
the cohort is exactly ``round(ratio * N)`` — the cluster-based
schedulers round the cohort up to a multiple of K, which at bench scale
collapses both ratios onto the same cohort). For each (codec, ratio)
cell it records:

* ``acc_vs_bytes`` — [cumulative_uplink_bytes, accuracy] per round: the
  headline accuracy-vs-communication trade-off curve;
* ``byte_reduction_at_target_x`` — the communication-efficiency claim:
  uplink bytes the dense run spends over its horizon divided by the
  bytes the codec needs to first reach within 1pp of the dense best
  accuracy. Each codec is allowed extra rounds past the dense horizon,
  capped at ``rounds * payload_ratio / gate`` so a codec can never
  "pass" while having spent more than ``1/gate`` of the dense bytes;
* the cost-model view — per-round ``msg_bits`` and the eq. (13)/(14)
  ``T_i``/``E_i`` sums, which shrink with the payload because the
  convex allocation prices the codec's actual bits-per-message;
* host overhead — ``wall_per_round_ms`` plus a direct
  ``encode_decode_ms`` timing of the jitted codec math on the cohort's
  (H, ...) delta tree (the per-round encode/decode cost, isolated from
  training).

Writes ``BENCH_comm_compress.json`` so future PRs track the trajectory.

    PYTHONPATH=src python -m benchmarks.bench_comm_compress [--smoke]

``--smoke`` runs tiny shapes and asserts the PR's acceptance bar: int8
and topk reach within 1pp of the uncompressed accuracy on >= ~4x fewer
uplink bytes (3.9x for int8 — the per-leaf f32 scale overhead makes its
exact payload ratio 32p/(8p+32L) < 4), with T_i/E_i reduced to match.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.core.framework import FrameworkConfig, HFLFramework
from repro.data import make_dataset, partition_noniid

N_DEVICES = 20
N_EDGES = 4
ROUNDS = 12
ALLOC_STEPS = 100
TOPK_FRAC = 0.1
ACC_TOL_PP = 1.0
# reduction gates: payload ratio the codec must beat — also caps the
# extra rounds it may take to reach the dense target accuracy
MIN_RATIO = {"none": 1.0, "bf16_delta": 1.9, "int8": 3.9, "topk": 4.0}


def _world(n_devices, n_edges, n_train, n_test, L, Q, seed=0):
    sp = cm.SystemParams(n_devices=n_devices, n_edges=n_edges,
                         d_range=(30, 60), L=L, Q=Q)
    pop = cm.sample_population(sp, seed=seed)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                n_test=n_test, seed=seed)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                           size_range=(15, 30), seed=seed)
    return sp, pop, fed


def _encode_decode_ms(codec_cfg, params, H, repeat=5):
    """Jitted codec round-trip on an (H, ...) cohort delta tree — the
    isolated per-message encode/decode cost (identity codec: ~0, it
    passes through untouched)."""
    delta = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None] * 1e-3, (H,) + p.shape)
        .astype(jnp.float32), params)
    resid = jax.tree.map(jnp.zeros_like, delta)
    key = jax.random.PRNGKey(0)

    @jax.jit
    def roundtrip(d, r):
        return comp.encode_decode(codec_cfg, key, d, r)

    out = jax.block_until_ready(roundtrip(delta, resid))    # compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = jax.block_until_ready(roundtrip(delta, resid))
    del out
    return (time.perf_counter() - t0) / repeat * 1e3


def _run_case(codec, ratio, sp, pop, fed, rounds, alloc_steps,
              topk_frac=TOPK_FRAC, seed=0):
    H = max(2, int(round(ratio * pop.n_devices)))
    ccfg = comp.CompressionConfig(codec=codec, topk_frac=topk_frac,
                                  seed=seed)
    cfg = FrameworkConfig(H=H, engine="fused", scheduler="fedavg",
                          seed=seed, alloc_steps=alloc_steps,
                          compression=ccfg)
    fw = HFLFramework(sp, pop, fed, cfg)
    raw_bits = comp.message_bits(comp.CompressionConfig(), fw.model_params)
    payload_ratio = raw_bits / fw.uplink_bits
    # extra rounds to chase the dense target accuracy, capped so that
    # reaching it still implies >= MIN_RATIO[codec] fewer bytes
    total_rounds = max(rounds, int(rounds * payload_ratio
                                   / MIN_RATIO[codec]))
    accs, cum_bytes, T, E = [], [], 0.0, 0.0
    acc_vs_bytes = []
    t0 = time.perf_counter()
    for i in range(total_rounds):
        rec = fw.run_round(i)
        accs.append(rec["acc"])
        cum_bytes.append((cum_bytes[-1] if cum_bytes else 0.0)
                         + rec["msg_bits"] / 8)
        acc_vs_bytes.append([cum_bytes[-1], rec["acc"]])
        if i < rounds:
            T += rec["T_i"]
            E += rec["E_i"]
    wall = (time.perf_counter() - t0) / total_rounds
    return {
        "codec": codec, "ratio": ratio, "H": H, "rounds": rounds,
        "total_rounds": total_rounds,
        "topk_frac": topk_frac if codec == "topk" else None,
        "uplink_bits_per_msg": float(fw.uplink_bits),
        "payload_ratio_x": float(payload_ratio),
        "msg_bits_per_round": fw.history[-1]["msg_bits"],
        "acc_vs_bytes": acc_vs_bytes,
        # matched-round stats over the dense horizon
        "best_acc": max(accs[:rounds]), "final_acc": accs[rounds - 1],
        "cum_uplink_bytes": cum_bytes[rounds - 1],
        "T": T, "E": E,
        "wall_per_round_ms": wall * 1e3,
        "encode_decode_ms": _encode_decode_ms(ccfg, fw.model_params, H),
    }


def _bytes_to_target(case, target_acc):
    """First point on the codec's curve reaching ``target_acc``."""
    for b, a in case["acc_vs_bytes"]:
        if a >= target_acc:
            return b
    return None


def run(out_json: str = "BENCH_comm_compress.json",
        n_devices: int = N_DEVICES, n_edges: int = N_EDGES,
        rounds: int = ROUNDS, n_train: int = 1200, n_test: int = 400,
        L: int = 3, Q: int = 3, alloc_steps: int = ALLOC_STEPS):
    sp, pop, fed = _world(n_devices, n_edges, n_train, n_test, L, Q)
    result = {"N": n_devices, "M": n_edges, "rounds": rounds,
              "L": L, "Q": Q, "topk_frac": TOPK_FRAC,
              "acc_tol_pp": ACC_TOL_PP, "cases": []}
    for ratio in (0.5, 0.3):
        base = None
        for codec in comp.CODECS:
            r = _run_case(codec, ratio, sp, pop, fed, rounds, alloc_steps)
            if codec == "none":
                base = r
            target = base["best_acc"] - ACC_TOL_PP / 100
            bt = _bytes_to_target(r, target)
            r["target_acc"] = target
            r["bytes_to_target"] = bt
            r["byte_reduction_at_target_x"] = (
                None if bt is None else base["cum_uplink_bytes"] / bt)
            r["byte_reduction_x"] = (base["cum_uplink_bytes"]
                                     / r["cum_uplink_bytes"])
            r["acc_delta_pp"] = 100 * (r["best_acc"] - base["best_acc"])
            result["cases"].append(r)
            bt_x = r["byte_reduction_at_target_x"]
            emit(f"comm_compress/{codec}_r{int(ratio * 100)}",
                 r["wall_per_round_ms"] * 1e3,
                 f"acc={r['best_acc']:.3f};x={r['byte_reduction_x']:.2f};"
                 f"x_at_target={'-' if bt_x is None else f'{bt_x:.2f}'};"
                 f"dacc={r['acc_delta_pp']:+.1f}pp;"
                 f"codec_ms={r['encode_decode_ms']:.1f}")

    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    return result


def run_smoke(out_json: str = "results/BENCH_comm_compress_smoke.json"):
    """Tiny-shape CI guard: asserts the PR's acceptance bar on the
    emitted JSON — int8/topk reach within 1pp of the dense accuracy on
    >= ~4x fewer uplink bytes, with the cost model priced to match."""
    result = run(out_json=out_json, n_devices=10, n_edges=3, rounds=10,
                 n_train=400, n_test=400, L=3, Q=3, alloc_steps=40)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert len(loaded["cases"]) == 2 * len(comp.CODECS)
    by_key = {(c["codec"], c["ratio"]): c for c in loaded["cases"]}
    for ratio in (0.5, 0.3):
        base = by_key[("none", ratio)]
        assert base["byte_reduction_x"] == 1.0
        assert len(base["acc_vs_bytes"]) == base["rounds"]
        for codec in ("int8", "topk"):
            c = by_key[(codec, ratio)]
            # per-round payload actually shrank >= the gate ...
            assert c["payload_ratio_x"] >= MIN_RATIO[codec], \
                (codec, ratio, c["payload_ratio_x"])
            assert c["byte_reduction_x"] >= MIN_RATIO[codec], \
                (codec, ratio, c["byte_reduction_x"])
            # ... and the dense accuracy (within 1pp) was reached on
            # >= gate-times fewer bytes (the total_rounds cap makes
            # reaching it at all sufficient; assert both anyway)
            assert c["bytes_to_target"] is not None, (codec, ratio)
            assert c["byte_reduction_at_target_x"] >= MIN_RATIO[codec], \
                (codec, ratio, c["byte_reduction_at_target_x"])
            # the cost model sees the smaller payload
            assert c["msg_bits_per_round"] < base["msg_bits_per_round"]
            assert c["E"] < base["E"] and c["T"] < base["T"]
        # int8 stochastic rounding + EF is near-lossless even at
        # matched rounds
        assert by_key[("int8", ratio)]["acc_delta_pp"] >= -ACC_TOL_PP
    emit("comm_compress/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert the acceptance ratios")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
