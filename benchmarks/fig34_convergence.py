"""Figs. 3/4: testing accuracy vs global iteration for IKC / VKC / FedAvg
at several cohort sizes H (reduced scale; orderings are the claim).

All repeats of a cell run through ONE vmapped ``SweepRunner`` engine
(the repeat axis is a vmap lane), instead of re-running the framework
per repeat: every round of every repeat is a single jitted dispatch.
Semantics match the original figure: fixed round-robin edge assignment
(``assign="mod"``), aggregation weighted by the actual federated
partition sizes (``sizes="fed"``), and no resource allocation
(``train_only=True`` — this figure only reads accuracy curves).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import REPEATS, emit, make_world
from repro.core.sweep import SweepRunner, build_scheduler


def run(iters: int = 10, h_values=(10, 20), out_json="results/fig34.json",
        shard: bool = False):
    built = [make_world("fmnist_syn", seed=r) for r in range(REPEATS)]
    sp = built[0][0]
    worlds = [(pop, fed) for _, pop, fed in built]
    runner = SweepRunner(sp, worlds, lr=0.03, alloc_steps=30, model_seed=0,
                         shard=shard)

    results = {}
    for H in h_values:
        for method in ("ikc", "vkc", "fedavg"):
            t0 = time.perf_counter()
            scheds = [build_scheduler(method, worlds[r][1], sp, H, K=10,
                                      lr=0.01, seed=r)
                      for r in range(REPEATS)]
            out = runner.run(scheds, n_rounds=iters, assign="mod",
                             seeds=list(range(REPEATS)), sizes="fed",
                             train_only=True)
            curves = out["acc"]                      # (REPEATS, iters)
            mean = curves.mean(axis=0)
            results[f"{method}_H{H}"] = {"mean": mean.tolist(),
                                         "std": curves.std(axis=0).tolist()}
            emit(f"fig34/{method}_H{H}",
                 (time.perf_counter() - t0) * 1e6,
                 f"final_acc={mean[-1]:.3f};auc={float(np.mean(mean)):.3f}")
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(results, f, indent=1)
    # paper claim: IKC ≥ VKC ≥ FedAvg in accuracy-AUC at matched H
    for H in h_values:
        auc = {m: float(np.mean(results[f"{m}_H{H}"]["mean"]))
               for m in ("ikc", "vkc", "fedavg")}
        emit(f"fig34/claim_ordering_H{H}", 0.0,
             f"ikc={auc['ikc']:.3f};vkc={auc['vkc']:.3f};"
             f"fedavg={auc['fedavg']:.3f};"
             f"pass={auc['ikc'] >= auc['fedavg'] - 0.01}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shard", action="store_true",
                    help="shard the repeat lanes over the local devices "
                         "(set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N before launch for CPU emulation)")
    run(shard=ap.parse_args().shard)
