"""Figs. 3/4: testing accuracy vs global iteration for IKC / VKC / FedAvg
at several cohort sizes H (reduced scale; orderings are the claim)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import REPEATS, emit, make_world
from repro.core.hfl import (evaluate_in_batches, hfl_global_iteration,
                            pad_device_data)
from repro.core.scheduling import (FedAvgScheduler, IKCScheduler,
                                   VKCScheduler, run_device_clustering)
from repro.models import cnn


def _train_curve(fed, sp, scheduler, iters: int, lr: float, seed: int):
    X, y, mask = pad_device_data(fed)
    key = jax.random.PRNGKey(seed)
    params = cnn.cnn_init(key, fed.X_test.shape[1:3], fed.X_test.shape[3])
    rng = np.random.default_rng(seed)
    accs = []
    for i in range(iters):
        sched = np.asarray(scheduler.schedule(rng))
        assign = np.asarray(sched % sp.n_edges)      # fixed assignment here
        params = hfl_global_iteration(
            cnn.cnn_apply, params, X[sched], y[sched], mask[sched],
            jnp.asarray(fed.sizes[sched], jnp.float32), jnp.asarray(assign),
            M=sp.n_edges, L=sp.L, Q=sp.Q, lr=lr)
        accs.append(evaluate_in_batches(cnn.cnn_apply, params,
                                        fed.X_test, fed.y_test))
    return accs


def _make_scheduler(name, fed, sp, H, seed):
    if name == "fedavg":
        return FedAvgScheduler(fed.n_devices, H)
    key = jax.random.PRNGKey(seed)
    X, y, mask = pad_device_data(fed)
    if name == "ikc":
        mini = cnn.mini_init(key)
        crop = jax.vmap(cnn.mini_preprocess)(
            X[:, :, :, :, :1], jax.random.split(key, fed.n_devices))
        labels, _ = run_device_clustering(key, cnn.mini_apply, mini, crop,
                                          y, mask, 10, sp.L, 0.01)
        return IKCScheduler(labels, max(1, H // 10))
    full = cnn.cnn_init(key, fed.X_test.shape[1:3], fed.X_test.shape[3])
    labels, _ = run_device_clustering(key, cnn.cnn_apply, full, X, y, mask,
                                      10, sp.L, 0.01)
    return VKCScheduler(labels, max(1, H // 10))


def run(iters: int = 10, h_values=(10, 20), out_json="results/fig34.json"):
    results = {}
    for H in h_values:
        for method in ("ikc", "vkc", "fedavg"):
            curves = []
            for r in range(REPEATS):
                sp, pop, fed = make_world("fmnist_syn", seed=r)
                t0 = time.perf_counter()
                sched = _make_scheduler(method, fed, sp, H, seed=r)
                accs = _train_curve(fed, sp, sched, iters, lr=0.03, seed=r)
                curves.append(accs)
            mean = np.mean(curves, axis=0)
            results[f"{method}_H{H}"] = {"mean": mean.tolist(),
                                         "std": np.std(curves, 0).tolist()}
            emit(f"fig34/{method}_H{H}",
                 (time.perf_counter() - t0) * 1e6,
                 f"final_acc={mean[-1]:.3f};auc={float(np.mean(mean)):.3f}")
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(results, f, indent=1)
    # paper claim: IKC ≥ VKC ≥ FedAvg in accuracy-AUC at matched H
    for H in h_values:
        auc = {m: float(np.mean(results[f"{m}_H{H}"]["mean"]))
               for m in ("ikc", "vkc", "fedavg")}
        emit(f"fig34/claim_ordering_H{H}", 0.0,
             f"ikc={auc['ikc']:.3f};vkc={auc['vkc']:.3f};"
             f"fedavg={auc['fedavg']:.3f};"
             f"pass={auc['ikc'] >= auc['fedavg'] - 0.01}")


if __name__ == "__main__":
    run()
