"""Micro-benchmark: per-arch HFL round cost/accuracy over the model zoo.

Runs the fused single-dispatch sweep engine (``SweepRunner.run(...,
fused=True)``) over every ``HFL_SMOKE_ARCHS`` payload — the paper CNN
plus one dense-transformer, one SSM and one MoE smoke config on the
synthetic sequence-classification task — and records, per arch:

  * ``model_bits`` (the quantity every cost-model term prices),
  * the accuracy trajectory over R rounds and the round costs T_i/E_i,
  * ``fused_wall_ms`` / ``round_ms`` host wall time (compile included —
    one dispatch per sweep, so this tracks trace+XLA cost per payload),
  * ``n_dispatches`` (must equal the CNN engine's: the fused scan is
    payload-agnostic, one dispatch regardless of pytree shape),
  * int8-codec uplink accounting: the engine's ``uplink_bits_per_msg``
    must equal ``compression.message_bits()`` on the arch's params
    exactly (embedding + stacked-expert leaves included).

Writes ``BENCH_model_zoo.json`` so future PRs track the trajectory.

    PYTHONPATH=src python -m benchmarks.bench_model_zoo [--smoke]

``--smoke`` runs tiny shapes and asserts the model-zoo acceptance
gates: >=2 non-CNN archs complete rounds with improving accuracy,
``n_dispatches`` matches the CNN engine, codec accounting is exact.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import (HFL_SMOKE_ARCHS, get_hfl_spec,
                                    get_smoke_config)
from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.core.sweep import SweepRunner, build_scheduler
from repro.data import make_dataset, make_seq_dataset, partition_noniid
from repro.utils import tree_bytes

ROUNDS = 3
ALLOC_STEPS = 25


def _world_for(arch, n_devices, n_edges, n_train, n_test, seed=0):
    sp = cm.SystemParams(n_devices=n_devices, n_edges=n_edges,
                         d_range=(6, 10))
    pop = cm.sample_population(sp, seed=seed)
    if arch == "hfl-cnn":
        X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                    n_test=n_test, seed=seed)
    else:
        vocab = min(257, get_smoke_config(arch).vocab_size)
        X, y, Xt, yt = make_seq_dataset(n_train=n_train, n_test=n_test,
                                        seed=seed, vocab_size=vocab)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                           size_range=(6, 10), seed=seed)
    return sp, pop, fed


def _bench_arch(arch, sp, pop, fed, rounds, H, lr):
    spec = get_hfl_spec(arch)
    params = spec.init_fn(jax.random.PRNGKey(0), fed)
    model_bits = tree_bytes(params) * 8

    t0 = time.perf_counter()
    runner = SweepRunner(sp, [(pop, fed)], lr=lr,
                         alloc_steps=ALLOC_STEPS, arch=arch)
    res = runner.run([build_scheduler("fedavg", fed, sp, H, seed=0)],
                     rounds, assign="geo", fused=True)
    wall = time.perf_counter() - t0

    # int8 lane: the engine's wire accounting must equal message_bits()
    ccfg = comp.CompressionConfig(codec="int8")
    runner_c = SweepRunner(sp, [(pop, fed)], lr=lr,
                           alloc_steps=ALLOC_STEPS, arch=arch,
                           compression=ccfg)
    res_c = runner_c.run([build_scheduler("fedavg", fed, sp, H, seed=0)],
                         rounds, assign="geo", fused=True)
    expect_bits = comp.message_bits(ccfg, params)
    assert res_c["uplink_bits_per_msg"] == expect_bits, (
        arch, res_c["uplink_bits_per_msg"], expect_bits)

    accs = [float(a) for a in res["acc"][0]]
    return {
        "arch": arch, "family": spec.family,
        "model_bits": float(model_bits),
        "rounds": rounds, "H": H, "lr": lr,
        "accs": accs, "final_acc": accs[-1],
        "T_i": [float(t) for t in res["T_i"][0]],
        "E_i": [float(e) for e in res["E_i"][0]],
        "n_dispatches": int(res["n_dispatches"]),
        "fused_wall_ms": wall * 1e3,
        "round_ms": wall * 1e3 / rounds,
        "int8_uplink_bits_per_msg": float(res_c["uplink_bits_per_msg"]),
        "int8_final_acc": float(res_c["acc"][0, -1]),
        "compression_x": float(model_bits / expect_bits),
    }


def run(out_json: str = "BENCH_model_zoo.json",
        archs=HFL_SMOKE_ARCHS, n_devices: int = 8, n_edges: int = 2,
        rounds: int = ROUNDS, n_train: int = 600, n_test: int = 128):
    H = max(2, n_devices // 2)
    result = {"N": n_devices, "M": n_edges, "rounds": rounds,
              "archs": []}
    for arch in archs:
        sp, pop, fed = _world_for(arch, n_devices, n_edges, n_train,
                                  n_test)
        lr = 0.01 if arch == "hfl-cnn" else 0.3
        r = _bench_arch(arch, sp, pop, fed, rounds, H, lr)
        result["archs"].append(r)
        emit(f"model_zoo/{arch}", r["round_ms"] * 1e3,
             f"acc={r['final_acc']:.3f};bits={r['model_bits']:.0f};"
             f"dispatches={r['n_dispatches']};x={r['compression_x']:.2f}")

    # the fused engine is payload-agnostic: every arch, CNN included,
    # runs its whole sweep in the same number of dispatches
    cnn_d = next(r["n_dispatches"] for r in result["archs"]
                 if r["arch"] == "hfl-cnn")
    assert all(r["n_dispatches"] == cnn_d for r in result["archs"])
    result["n_dispatches"] = cnn_d

    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    return result


def run_smoke(out_json: str = "results/BENCH_model_zoo_smoke.json"):
    """Tiny-shape CI guard asserting the model-zoo acceptance gates."""
    result = run(out_json=out_json, n_devices=8, n_edges=2, rounds=4,
                 n_train=360, n_test=64)
    with open(out_json) as fh:
        loaded = json.load(fh)
    by_arch = {r["arch"]: r for r in loaded["archs"]}
    assert "hfl-cnn" in by_arch and len(by_arch) >= 3
    improving = [a for a, r in by_arch.items()
                 if a != "hfl-cnn" and r["accs"][-1] > r["accs"][0]]
    assert len(improving) >= 2, improving     # transformer + ssm at least
    families = {r["family"] for r in loaded["archs"]}
    assert {"cnn", "dense"} <= families and len(families) >= 3
    for r in loaded["archs"]:
        assert r["n_dispatches"] == loaded["n_dispatches"]
        assert r["int8_uplink_bits_per_msg"] < r["model_bits"]
    emit("model_zoo/smoke", 0.0,
         f"pass=True;improving={len(improving)}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert the model-zoo gates only")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
