"""§Roofline: three-term roofline per (arch x shape) from the dry-run.

  compute term    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory term     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective term = collective_bytes / (chips * 50e9 B/s ICI)

HLO_FLOPs/bytes/collective_bytes are the probe-corrected per-device
totals from results/probes.json (the raw dryrun.json numbers undercount
scan bodies); chips divide out because our sources are already
per-device. MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*tokens
(decode) gives the useful-compute ratio.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from benchmarks.common import emit
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config, variant_for_shape
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

CHIPS = 256


def model_flops(arch: str, shape_name: str) -> float:
    """Useful FLOPs per step (whole job, not per device)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = variant_for_shape(get_config(arch), shape)
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_terms(rec: Dict, probe: Optional[Dict]) -> Dict:
    """rec: dryrun.json record; probe: probes.json record (or None)."""
    if probe and "flops" in probe:
        flops_dev = probe["flops"]
        bytes_dev = probe["bytes"]
        coll_dev = probe["coll"]
        src = "probe"
    else:
        flops_dev = rec["cost"].get("flops", 0.0)
        bytes_dev = rec["cost"].get("bytes accessed", 0.0)
        coll_dev = sum(v["bytes"] for v in rec["collectives"].values())
        src = "raw(scan-undercounted)"
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW_PER_LINK
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops_dev * CHIPS) if flops_dev else 0.0
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf, "useful_ratio": useful, "source": src}


RECOMMEND = {
    "compute": "reduce recompute (remat policy) / raise MoE capacity "
               "utilisation; compute term is floor-bound by model FLOPs",
    "memory": "fuse/bf16-ify the biggest HBM streams (weights are "
              "re-read per microbatch: fewer, larger microbatches)",
    "collective": "overlap collectives with compute; move the dominant "
                  "all-gather to the smaller mesh axis or shard the "
                  "producing tensor differently",
}


def run(dryrun_path="results/dryrun.json", probes_path="results/probes.json",
        out_path="results/roofline.json", mesh="16x16"):
    if not os.path.exists(dryrun_path):
        emit("roofline/missing", 0.0, f"no {dryrun_path}; run dryrun first")
        return
    with open(dryrun_path) as f:
        recs = [r for r in json.load(f) if r.get("mesh") == mesh
                and "error" not in r]
    probes = {}
    if os.path.exists(probes_path):
        with open(probes_path) as f:
            probes = {(p["arch"], p["shape"]): p for p in json.load(f)
                      if "error" not in p}
    table = []
    for r in recs:
        t = roofline_terms(r, probes.get((r["arch"], r["shape"])))
        t.update(arch=r["arch"], shape=r["shape"],
                 temp_gb=r["memory"]["temp_bytes"] / 1e9,
                 args_gb=r["memory"]["argument_bytes"] / 1e9,
                 fits_16g=(r["memory"]["temp_bytes"]
                           + r["memory"]["argument_bytes"]) < 16e9,
                 recommend=RECOMMEND[t["dominant"]])
        table.append(t)
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"comp={t['t_compute_s']:.4f}s;mem={t['t_memory_s']:.4f}s;"
             f"coll={t['t_collective_s']:.4f}s;dom={t['dominant']};"
             f"useful={t['useful_ratio']:.2f};src={t['source']}")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(table, f, indent=1)
    n_dom = {}
    for t in table:
        n_dom[t["dominant"]] = n_dom.get(t["dominant"], 0) + 1
    emit("roofline/summary", 0.0,
         f"pairs={len(table)};dominants={n_dom}")


if __name__ == "__main__":
    run()
