"""Micro-benchmark: event-driven async HFL engine vs the sync round.

Sweeps fleet-fault scenarios at the paper's 50% / 30% scheduling ratios:

  * ``sync``      — degenerate always-on trace, wait-for-all buffers
                    (== the synchronous ``round_step`` by the parity
                    contract pinned in ``tests/test_async_engine.py``);
  * ``dropout``   — alternating-renewal churn with mean session length
                    tuned to the degenerate round makespan;
  * ``straggler`` — 30% of the fleet at 5x latency, FedBuff-style
                    partial buffers so flushes stop waiting on them.

For each case it records the accuracy-vs-virtual-wall-clock curve
(``acc_curve``: [t_virtual_s, accuracy] per round), the staleness/waste
accounting, and the *host* wall time per round (the event loop +
dispatch overhead — the perf-tracked ``*_ms`` fields), plus a direct
``round_step`` timing as the sync engine reference. Writes
``BENCH_async_engine.json`` so future PRs track the trajectory.

    PYTHONPATH=src python -m benchmarks.bench_async_engine [--smoke]

``--smoke`` runs tiny shapes and only asserts the benchmark runs
end-to-end and emits valid JSON (CI guard, no timing claims).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core.async_engine import AsyncConfig, AsyncHFLEngine
from repro.core.framework import round_step
from repro.data import make_dataset, partition_noniid

N_DEVICES = 20
N_EDGES = 4
ROUNDS = 4
ALLOC_STEPS = 100


def _world(n_devices, n_edges, n_train, n_test, L, Q, seed=0):
    sp = cm.SystemParams(n_devices=n_devices, n_edges=n_edges,
                         d_range=(30, 60), L=L, Q=Q)
    pop = cm.sample_population(sp, seed=seed)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                n_test=n_test, seed=seed)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                           size_range=(15, 30), seed=seed)
    return sp, pop, fed


def _trace_for(case, sp, pop, fed, H, T_deg, seed):
    n = pop.n_devices
    if case == "sync":
        return cm.AvailabilityTrace.always_on(n), None
    if case == "dropout":
        ap = cm.AvailabilityParams(p_offline0=0.1, mean_up_s=T_deg,
                                   mean_down_s=T_deg / 4)
        return cm.sample_availability(ap, n, seed=seed,
                                      max_toggles=256), None
    if case == "straggler":
        ap = cm.AvailabilityParams(straggler_frac=0.3,
                                   straggler_scale=5.0)
        buf = max(1, H // (2 * pop.n_edges))
        return cm.sample_availability(ap, n, seed=seed), buf
    raise ValueError(case)


def _run_case(case, ratio, sp, pop, fed, rounds, T_deg, seed=0):
    H = max(2, int(round(ratio * pop.n_devices)))
    trace, buf = _trace_for(case, sp, pop, fed, H, T_deg, seed)
    cfg = AsyncConfig(H=H, scheduler="fedavg", alloc_steps=ALLOC_STEPS,
                      seed=seed, buffer_size=buf, staleness_exp=0.5)
    eng = AsyncHFLEngine(sp, pop, fed, cfg, trace=trace)
    t0 = time.perf_counter()
    for _ in range(rounds):
        eng.step_round(collect_eval=True)
    wall = (time.perf_counter() - t0) / rounds
    s = eng.summary()
    return {
        "case": case, "ratio": ratio, "H": H, "rounds": rounds,
        "buffer_size": buf,
        "acc_curve": [[r["t"], r["acc"]] for r in s["history"]],
        "final_acc": s["final_acc"], "t_virtual": s["t_virtual"],
        "T": s["T"], "E": s["E"], "n_updates": s["n_updates"],
        "n_stale": s["n_stale"], "n_aborted": s["n_aborted"],
        "wasted_j": s["wasted_j"],
        "wall_per_round_ms": wall * 1e3,
    }


def _sync_round_ms(sp, pop, fed, H, repeat=3, seed=0):
    """Direct fused ``round_step`` timing — the sync engine reference."""
    probe = AsyncHFLEngine(sp, pop, fed,
                           AsyncConfig(H=H, alloc_steps=ALLOC_STEPS,
                                       seed=seed))
    sched = np.arange(H)
    assign = jnp.asarray(sched % pop.n_edges, jnp.int32)
    spp = probe.sp

    def one(params):
        out, _ = round_step(
            probe.apply_fn, spp, params,
            pop.u[sched], pop.D[sched], pop.p[sched], pop.g[sched],
            pop.g_cloud, pop.B_m,
            probe.X[sched], probe.y[sched], probe.mask[sched],
            pop.D[sched], assign, 0.01,
            M=pop.n_edges, L=spp.L, Q=spp.Q, alloc_steps=ALLOC_STEPS)
        return jax.block_until_ready(out)

    params = one(probe.model_params)                 # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        params = one(params)
    return (time.perf_counter() - t0) / repeat * 1e3


def run(out_json: str = "BENCH_async_engine.json",
        n_devices: int = N_DEVICES, n_edges: int = N_EDGES,
        rounds: int = ROUNDS, n_train: int = 1200, n_test: int = 300,
        L: int = 3, Q: int = 3):
    sp, pop, fed = _world(n_devices, n_edges, n_train, n_test, L, Q)

    result = {"N": n_devices, "M": n_edges, "rounds": rounds,
              "L": L, "Q": Q, "cases": []}
    for ratio in (0.5, 0.3):
        # degenerate probe pins the round makespan the churn scales from
        H = max(2, int(round(ratio * n_devices)))
        probe = AsyncHFLEngine(sp, pop, fed,
                               AsyncConfig(H=H, alloc_steps=ALLOC_STEPS))
        T_deg = probe.step_round(collect_eval=False)["T_i"]
        for case in ("sync", "dropout", "straggler"):
            r = _run_case(case, ratio, sp, pop, fed, rounds, T_deg)
            result["cases"].append(r)
            acc = "-" if r["final_acc"] is None else f"{r['final_acc']:.3f}"
            emit(f"async_engine/{case}_r{int(ratio * 100)}",
                 r["wall_per_round_ms"] * 1e3,
                 f"acc={acc};T_virtual={r['t_virtual']:.0f}s;"
                 f"stale={r['n_stale']};aborted={r['n_aborted']}")
        result[f"sync_round_r{int(ratio * 100)}_ms"] = _sync_round_ms(
            sp, pop, fed, H)

    # the event loop costs more host time than one fused dispatch; track
    # the overhead ratio so it can't silently explode
    sync_ms = result["sync_round_r50_ms"]
    async_ms = next(c["wall_per_round_ms"] for c in result["cases"]
                    if c["case"] == "sync" and c["ratio"] == 0.5)
    result["async_overhead_x"] = async_ms / max(sync_ms, 1e-9)
    emit("async_engine/overhead", 0.0,
         f"async={async_ms:.0f}ms;sync={sync_ms:.0f}ms;"
         f"x={result['async_overhead_x']:.1f}")

    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    return result


def run_smoke(out_json: str = "results/BENCH_async_engine_smoke.json"):
    """Tiny-shape CI guard: runs end-to-end, validates the emitted JSON."""
    result = run(out_json=out_json, n_devices=10, n_edges=3, rounds=2,
                 n_train=300, n_test=120, L=2, Q=2)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["N"] == 10 and len(loaded["cases"]) == 6
    for c in loaded["cases"]:
        assert c["wall_per_round_ms"] > 0
        assert len(c["acc_curve"]) == c["rounds"]
    sync = [c for c in loaded["cases"] if c["case"] == "sync"]
    assert all(c["n_stale"] == 0 and c["n_aborted"] == 0 for c in sync)
    emit("async_engine/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
