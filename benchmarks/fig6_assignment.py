"""Fig. 6: assignment strategies — T_i, E_i, objective (17), assigning
latency: D3QN vs HFEL-100 / HFEL-300 vs geographic."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.assignment import DRLAssigner, GeoAssigner, HFELAssigner
from repro.core.assignment.hfel import total_objective
from repro.core.cost_model import SystemParams
from repro.drl.train import make_training_population


def run(trained_trainer=None, n_pops: int = 12, H: int = 20,
        out_json="results/fig6.json"):
    sp = SystemParams(n_edges=5, lam=1.0)
    rng = np.random.default_rng(0)
    strategies = {
        "geo": GeoAssigner(sp),
        "hfel100": HFELAssigner(sp, n_transfer=100, n_exchange=100,
                                alloc_steps=100),
        "hfel300": HFELAssigner(sp, n_transfer=100, n_exchange=300,
                                alloc_steps=100),
    }
    if trained_trainer is not None:
        strategies["d3qn"] = DRLAssigner(sp, trained_trainer.params)

    acc = {k: {"T": [], "E": [], "obj": [], "lat": []} for k in strategies}
    sched = np.arange(H)
    for p in range(n_pops):
        pop = make_training_population(sp, H, seed=500 + p)
        for name, strat in strategies.items():
            t0 = time.perf_counter()
            a, _ = strat.assign(pop, sched, rng)
            lat = time.perf_counter() - t0
            obj, T_m, E_m = total_objective(sp, pop, sched, np.asarray(a),
                                            alloc_steps=100)
            acc[name]["T"].append(float(T_m.max()))
            acc[name]["E"].append(float(E_m.sum()))
            acc[name]["obj"].append(obj)
            acc[name]["lat"].append(lat)

    os.makedirs("results", exist_ok=True)
    summary = {k: {m: float(np.mean(v)) for m, v in d.items()}
               for k, d in acc.items()}
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    for name, s in summary.items():
        emit(f"fig6/{name}", s["lat"] * 1e6,
             f"T_i={s['T']:.1f};E_i={s['E']:.1f};obj={s['obj']:.1f}")
    # paper claims: hfel300 obj <= hfel100 <= geo; d3qn ~ hfel with
    # geo-like latency
    ok = summary["hfel300"]["obj"] <= summary["hfel100"]["obj"] * 1.02 <= \
        summary["geo"]["obj"] * 1.05
    emit("fig6/claim_search_improves", 0.0, f"pass={bool(ok)}")
    if "d3qn" in summary:
        fast = summary["d3qn"]["lat"] < 0.2 * summary["hfel300"]["lat"]
        emit("fig6/claim_d3qn_fast", 0.0,
             f"pass={bool(fast)};d3qn_obj={summary['d3qn']['obj']:.1f};"
             f"hfel300_obj={summary['hfel300']['obj']:.1f}")


if __name__ == "__main__":
    run()
