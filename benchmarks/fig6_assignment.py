"""Fig. 6: assignment strategies — T_i, E_i, objective (17), assigning
latency: D3QN vs HFEL-100 / HFEL-300 vs geographic.

Assignment latency is still timed per population (that is the measured
quantity), but objective evaluation batches ALL populations' per-edge
resource allocations into one ``allocate_batch`` call per strategy
(P x M edge problems in a single vmapped jit dispatch). The HFEL
strategies run the batched K-candidate search engine by default
(``hfel_search="serial"`` restores the one-trial oracle);
``benchmarks/bench_hfel_search.py`` tracks the serial-vs-batched
wall-time gap.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core import resource as ra
from repro.core.assignment import DRLAssigner, GeoAssigner, HFELAssigner
from repro.core.cost_model import SystemParams
from repro.drl.train import make_training_population


def batched_objectives(sp, pops, sched, assigns, alloc_steps: int):
    """(J, T_m, E_m) for P (population, assignment) pairs in one solve.

    Stacks every population's (M, H) edge problems into a (P*M, H)
    batch for ``allocate_batch``, then adds the per-population cloud
    constants. Returns arrays (P,), (P, M), (P, M).
    """
    P = len(pops)
    M = pops[0].n_edges
    ins = [ra.gather_edge_inputs(pop, sched, a)
           for pop, a in zip(pops, assigns)]
    stack = [jnp.concatenate([i[k] for i in ins]) for k in range(4)]
    B = jnp.concatenate([i[4] for i in ins])
    mask = jnp.concatenate([i[5] for i in ins])
    res = ra.allocate_batch(sp, stack[0], stack[1], stack[2], stack[3],
                            B, mask, steps=alloc_steps)
    T_edge = np.asarray(res.T_edge).reshape(P, M)
    E_edge = np.asarray(res.E_edge).reshape(P, M)
    cloud = [cm.cloud_cost(sp, pop.g_cloud) for pop in pops]
    T_cl = np.stack([np.asarray(c[0]) for c in cloud])
    E_cl = np.stack([np.asarray(c[1]) for c in cloud])
    T_m = T_edge + T_cl
    E_m = E_edge + E_cl
    J = E_m.sum(axis=1) + sp.lam * T_m.max(axis=1)
    return J, T_m, E_m


def run(trained_trainer=None, n_pops: int = 12, H: int = 20,
        out_json="results/fig6.json", hfel_search: str = "batched",
        hfel_candidates: int = 16):
    sp = SystemParams(n_edges=5, lam=1.0)
    rng = np.random.default_rng(0)
    strategies = {
        "geo": GeoAssigner(sp),
        "hfel100": HFELAssigner(sp, n_transfer=100, n_exchange=100,
                                alloc_steps=100, search=hfel_search,
                                n_candidates=hfel_candidates),
        "hfel300": HFELAssigner(sp, n_transfer=100, n_exchange=300,
                                alloc_steps=100, search=hfel_search,
                                n_candidates=hfel_candidates),
    }
    if trained_trainer is not None:
        strategies["d3qn"] = DRLAssigner(sp, trained_trainer.params)

    sched = np.arange(H)
    pops = [make_training_population(sp, H, seed=500 + p)
            for p in range(n_pops)]
    acc = {}
    for name, strat in strategies.items():
        assigns, lats = [], []
        for pop in pops:
            t0 = time.perf_counter()
            a, _ = strat.assign(pop, sched, rng)
            lats.append(time.perf_counter() - t0)
            assigns.append(np.asarray(a))
        J, T_m, E_m = batched_objectives(sp, pops, sched, assigns,
                                         alloc_steps=100)
        acc[name] = {"T": T_m.max(axis=1).tolist(),
                     "E": E_m.sum(axis=1).tolist(),
                     "obj": J.tolist(), "lat": lats}
        if name == "d3qn":
            # multi-population fast path: ALL populations' greedy
            # assignments in one dispatch; must agree with the per-
            # population loop, latency amortises across the batch
            strat.assign_batch(pops, sched)            # compile warmup
            t0 = time.perf_counter()
            a_b, _ = strat.assign_batch(pops, sched)
            lat_b = (time.perf_counter() - t0) / len(pops)
            match = all(np.array_equal(a_b[i], assigns[i])
                        for i in range(len(pops)))
            emit("fig6/d3qn_batched", lat_b * 1e6,
                 f"pops={len(pops)};matches_per_pop={bool(match)}")

    os.makedirs("results", exist_ok=True)
    summary = {k: {m: float(np.mean(v)) for m, v in d.items()}
               for k, d in acc.items()}
    with open(out_json, "w") as f:
        json.dump(summary, f, indent=1)
    for name, s in summary.items():
        emit(f"fig6/{name}", s["lat"] * 1e6,
             f"T_i={s['T']:.1f};E_i={s['E']:.1f};obj={s['obj']:.1f}")
    # paper claims: hfel300 obj <= hfel100 <= geo; d3qn ~ hfel with
    # geo-like latency
    ok = summary["hfel300"]["obj"] <= summary["hfel100"]["obj"] * 1.02 <= \
        summary["geo"]["obj"] * 1.05
    emit("fig6/claim_search_improves", 0.0, f"pass={bool(ok)}")
    if "d3qn" in summary:
        fast = summary["d3qn"]["lat"] < 0.2 * summary["hfel300"]["lat"]
        emit("fig6/claim_d3qn_fast", 0.0,
             f"pass={bool(fast)};d3qn_obj={summary['d3qn']['obj']:.1f};"
             f"hfel300_obj={summary['hfel300']['obj']:.1f}")


if __name__ == "__main__":
    run()
