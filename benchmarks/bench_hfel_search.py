"""Micro-benchmark: serial vs batched K-candidate HFEL search.

Times a full HFEL-300 assignment search (100 transfer + 300 exchange
trials) under both engines on the same worlds and seeds:

  * ``search="serial"`` — the literature-faithful accept/reject loop,
    one 2-edge ``allocate_batch`` dispatch per trial;
  * ``search="batched"`` — rounds of K candidate moves, all affected
    edges of a round solved in ONE flat ``(K*2, H)`` dispatch, trial
    re-solves warm-started from the incumbent edge solutions.

Emits CSV lines (benchmarks.common.emit) and writes
``BENCH_hfel_search.json`` (serial/batched wall-time + objective parity
at M=10, H=50/100) so future PRs can track the perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_hfel_search [--smoke]

``--smoke`` runs tiny shapes with a tiny budget and only asserts the
benchmark runs end-to-end and emits valid JSON (CI guard, no timing
claims).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core.assignment.hfel import HFELAssigner

M_EDGES = 10
H_VALUES = (50, 100)
N_TRANSFER = 100
N_EXCHANGE = 300          # HFEL-300 budget
ALLOC_STEPS = 100
N_CANDIDATES = 16
SEEDS = (0, 1, 2)


def _time_engine(assigner, pop, sched, seeds):
    """Mean wall-time and mean objective over per-seed searches (the
    first, compile-bearing run is warmup and untimed)."""
    assigner.assign(pop, sched, np.random.default_rng(99))
    times, objs = [], []
    for seed in seeds:
        t0 = time.perf_counter()
        _, j = assigner.assign(pop, sched, np.random.default_rng(seed))
        times.append(time.perf_counter() - t0)
        objs.append(j)
    return float(np.mean(times)), float(np.mean(objs))


def run(out_json: str = "BENCH_hfel_search.json", m_edges: int = M_EDGES,
        h_values=H_VALUES, n_transfer: int = N_TRANSFER,
        n_exchange: int = N_EXCHANGE, alloc_steps: int = ALLOC_STEPS,
        n_candidates: int = N_CANDIDATES, seeds=SEEDS,
        check_speedup: bool = True):
    cases = {}
    for H in h_values:
        sp = cm.SystemParams(n_devices=H, n_edges=m_edges)
        pop = cm.sample_population(sp, seed=0)
        sched = np.arange(H)
        common = dict(n_transfer=n_transfer, n_exchange=n_exchange,
                      alloc_steps=alloc_steps)
        t_ser, j_ser = _time_engine(
            HFELAssigner(sp, search="serial", **common), pop, sched, seeds)
        t_bat, j_bat = _time_engine(
            HFELAssigner(sp, search="batched", n_candidates=n_candidates,
                         **common), pop, sched, seeds)
        case = {
            "serial_s": t_ser, "batched_s": t_bat,
            "speedup": t_ser / t_bat,
            "serial_obj": j_ser, "batched_obj": j_bat,
            "obj_ratio": j_bat / j_ser,
        }
        cases[f"H{H}"] = case
        emit(f"hfel_search/serial_H{H}", t_ser * 1e6,
             f"M={m_edges};budget={n_transfer}+{n_exchange};J={j_ser:.1f}")
        emit(f"hfel_search/batched_H{H}", t_bat * 1e6,
             f"K={n_candidates};speedup={case['speedup']:.1f}x;"
             f"J={j_bat:.1f};obj_ratio={case['obj_ratio']:.3f}")

    result = {
        "M": m_edges, "n_transfer": n_transfer, "n_exchange": n_exchange,
        "alloc_steps": alloc_steps, "n_candidates": n_candidates,
        "seeds": list(seeds), "cases": cases,
    }
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)

    if check_speedup:
        big = cases[f"H{max(h_values)}"]
        emit("hfel_search/claim_batched_3x", 0.0,
             f"pass={big['speedup'] >= 3.0 and big['obj_ratio'] <= 1.02};"
             f"speedup={big['speedup']:.1f}x;"
             f"obj_ratio={big['obj_ratio']:.3f}")
    return result


def run_smoke(out_json: str = "results/BENCH_hfel_search_smoke.json"):
    """Tiny-shape CI guard: runs end-to-end, validates the emitted JSON."""
    result = run(out_json=out_json, m_edges=3, h_values=(8,), n_transfer=6,
                 n_exchange=10, alloc_steps=20, n_candidates=4,
                 seeds=(0,), check_speedup=False)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["cases"]["H8"]["serial_s"] > 0
    assert loaded["cases"]["H8"]["batched_s"] > 0
    assert result["M"] == 3
    emit("hfel_search/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
