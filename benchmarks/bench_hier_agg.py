"""Micro-benchmark: Pallas ``hier_agg`` aggregation vs the XLA einsum.

Two measurements, tracked in ``BENCH_hier_agg.json``:

* **raw aggregate** — the fused masked-weight kernel
  (``masked_aggregate``: one-hot + sizes in, normalised panel built
  in-kernel) against the einsum oracle that materialises the (M, H)
  weight panel, sweeping the flattened model size P from 10^4 to 10^7
  at the paper's cohort shapes (M=5/H=50 reduced scale, M=10/H=100
  HFEL-comparison scale). P is the axis that matters: the kernel's
  whole point is streaming the (H, P) delta matrix through VMEM once
  in 512-lane blocks.
* **end-to-end round** — the fused ``round_step`` with
  ``agg_kernel=True`` vs ``False`` at a model large enough for the
  aggregation to register (a wide linear probe), pinning that the route
  stays plumbed through the real engine and that both backends return
  identical costs.

On the CPU container the kernel runs in Pallas *interpret* mode, so the
absolute kernel timings are emulation overhead, not TPU bandwidth — the
JSON records them anyway (layout-ready for a TPU run, where the same
sweep exercises the MXU path).

    PYTHONPATH=src python -m benchmarks.bench_hier_agg [--smoke]

``--smoke`` runs tiny shapes and only asserts the benchmark runs
end-to-end and emits valid JSON (CI guard, no timing claims).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core.framework import round_step
from repro.kernels.hier_agg.ops import masked_aggregate
from repro.kernels.hier_agg.ref import masked_aggregate_ref

SHAPES = ((5, 50), (10, 100))              # (M, H): paper / HFEL scales
P_SWEEP = (10_000, 100_000, 300_000, 1_000_000, 10_000_000)
# Pallas interpret mode emulates the grid step-by-step, so its wall time
# grows superlinearly in P/BP on CPU (measured: ~30 ms at P=1e4, ~2.2 s
# at P=1e5 for M=10/H=100). Off-TPU the sweep stops at this cap and
# records the larger P rows as skipped — the sweep axis (and the JSON
# layout) stays intact for a TPU run, where the compiled kernel streams
# all five points.
P_CAP_INTERPRET = 300_000
REPEAT = 3
ROUND_FEATS = 512                          # linear-probe width for e2e


def _time(fn, *args, repeat: int = REPEAT):
    jax.block_until_ready(fn(*args))             # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def _agg_inputs(M: int, H: int, P: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, M, H)
    mask = jnp.asarray(
        (assign[None, :] == np.arange(M)[:, None]).astype(np.float32))
    sizes = jnp.asarray(rng.uniform(50, 90, H).astype(np.float32))
    d = jnp.asarray(rng.normal(0, 1, (H, P)).astype(np.float32))
    return mask, sizes, d


def _sweep_raw(shapes, p_sweep, repeat):
    ref_jit = jax.jit(masked_aggregate_ref)
    interpret = jax.default_backend() != "tpu"
    rows = []
    for M, H in shapes:
        for P in p_sweep:
            if interpret and P > P_CAP_INTERPRET:
                rows.append({"M": M, "H": H, "P": P,
                             "skipped": "interpret-mode emulation too "
                                        f"slow past P={P_CAP_INTERPRET}"})
                emit(f"hier_agg/raw_M{M}_H{H}_P{P}", 0.0,
                     "skipped=interpret")
                continue
            mask, sizes, d = _agg_inputs(M, H, P)
            rep = repeat if P <= 100_000 else 1
            t_k = _time(lambda: masked_aggregate(mask, sizes, d),
                        repeat=rep)
            t_e = _time(lambda: ref_jit(mask, sizes, d), repeat=rep)
            gb = (H * P + M * P) * 4 / 1e9   # streamed bytes, f32
            rows.append({
                "M": M, "H": H, "P": P,
                "kernel_ms": t_k * 1e3, "einsum_ms": t_e * 1e3,
                "kernel_over_einsum": t_k / t_e,
                "kernel_gbps": gb / t_k, "einsum_gbps": gb / t_e,
            })
            emit(f"hier_agg/raw_M{M}_H{H}_P{P}", t_k * 1e6,
                 f"einsum_us={t_e * 1e6:.1f};ratio={t_k / t_e:.2f}")
    return rows


def _linear_apply(params, X):
    return X.reshape(X.shape[0], -1) @ params["w"]


def _round_world(M, H, feats, seed=0):
    sp = cm.SystemParams(n_devices=H, n_edges=M)
    pop = cm.sample_population(sp, seed=seed)
    rng = np.random.default_rng(seed)
    sched = np.arange(H)
    assign = rng.integers(0, M, H)
    Dmax = 8
    X = jnp.asarray(rng.normal(0, 1, (H, Dmax, feats)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, (H, Dmax)).astype(np.int32))
    mask = jnp.ones((H, Dmax), jnp.float32)
    w0 = {"w": jnp.asarray(rng.normal(0, 0.1, (feats, 3))
                           .astype(np.float32))}
    return sp, pop, sched, assign, X, y, mask, w0


def _e2e_round(M, H, feats, alloc_steps, repeat):
    sp, pop, sched, assign, X, y, mask, w0 = _round_world(M, H, feats)

    def one(agg_kernel):
        w, (T_i, E_i, _, _, _, _) = round_step(
            _linear_apply, sp, w0, pop.u[sched], pop.D[sched],
            pop.p[sched], pop.g[sched], pop.g_cloud, pop.B_m, X, y, mask,
            pop.D[sched], jnp.asarray(assign), 0.05, M=M, L=sp.L, Q=sp.Q,
            alloc_steps=alloc_steps, agg_kernel=agg_kernel)
        return w, T_i, E_i

    t_kernel = _time(lambda: one(True), repeat=repeat)
    t_einsum = _time(lambda: one(False), repeat=repeat)
    w_k, T_k, _ = one(True)
    w_e, T_e, _ = one(False)
    np.testing.assert_allclose(np.asarray(w_k["w"]), np.asarray(w_e["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(T_k), float(T_e), rtol=1e-6)
    return {
        "M": M, "H": H, "model_params": feats * 3,
        "alloc_steps": alloc_steps,
        "round_kernel_ms": t_kernel * 1e3,
        "round_einsum_ms": t_einsum * 1e3,
        "kernel_over_einsum": t_kernel / t_einsum,
    }


def run(out_json: str = "BENCH_hier_agg.json", shapes=SHAPES,
        p_sweep=P_SWEEP, repeat: int = REPEAT, round_feats: int = ROUND_FEATS,
        alloc_steps: int = 100):
    result = {
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "repeat": repeat,
        "raw_aggregate": _sweep_raw(shapes, p_sweep, repeat),
        "round_step": _e2e_round(shapes[0][0], shapes[0][1], round_feats,
                                 alloc_steps, repeat),
    }
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)

    rs = result["round_step"]
    emit("hier_agg/round_kernel", rs["round_kernel_ms"] * 1e3,
         f"einsum_ms={rs['round_einsum_ms']:.1f};"
         f"ratio={rs['kernel_over_einsum']:.2f};"
         f"params={rs['model_params']}")
    return result


def run_smoke(out_json: str = "results/BENCH_hier_agg_smoke.json"):
    """Tiny-shape CI guard: runs end-to-end, validates the emitted JSON."""
    result = run(out_json=out_json, shapes=((3, 8),), p_sweep=(4096,),
                 repeat=1, round_feats=16, alloc_steps=25)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["raw_aggregate"][0]["kernel_ms"] > 0
    assert loaded["round_step"]["round_kernel_ms"] > 0
    emit("hier_agg/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
