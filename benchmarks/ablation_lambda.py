"""Ablation: the λ delay/energy trade-off (paper §III-C: "the value of λ
is determined based on the specific requirements of the practical
scenarios") and non-IID severity (majority_frac) sensitivity of IKC.

Standalone (not part of benchmarks.run defaults):
    PYTHONPATH=src python -m benchmarks.ablation_lambda
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.core import cost_model as cm
from repro.core.assignment import HFELAssigner
from repro.core.assignment.hfel import total_objective
from repro.drl.train import make_training_population


def lambda_sweep(lams=(0.1, 1.0, 10.0), H=20, n_pops=4):
    """Higher λ must never increase optimised delay T_i (and generally
    trades energy for it) — the allocator/assigner react to λ."""
    rows = {}
    for lam in lams:
        sp = cm.SystemParams(n_edges=5, lam=lam)
        hfel = HFELAssigner(sp, n_transfer=60, n_exchange=120,
                            alloc_steps=100)
        Ts, Es = [], []
        for p in range(n_pops):
            pop = make_training_population(sp, H, seed=900 + p)
            rng = np.random.default_rng(p)
            a, _ = hfel.assign(pop, np.arange(H), rng)
            _, T_m, E_m = total_objective(sp, pop, np.arange(H),
                                          np.asarray(a), alloc_steps=100)
            Ts.append(T_m.max())
            Es.append(E_m.sum())
        rows[lam] = (float(np.mean(Ts)), float(np.mean(Es)))
        emit(f"ablation/lambda_{lam}", 0.0,
             f"T_i={rows[lam][0]:.1f};E_i={rows[lam][1]:.2f}")
    lam_sorted = sorted(rows)
    t_monotone = all(rows[a][0] >= rows[b][0] * 0.9
                     for a, b in zip(lam_sorted, lam_sorted[1:]))
    emit("ablation/lambda_tradeoff", 0.0,
         f"delay_nonincreasing_with_lambda={t_monotone}")
    os.makedirs("results", exist_ok=True)
    with open("results/ablation_lambda.json", "w") as f:
        json.dump({str(k): v for k, v in rows.items()}, f, indent=1)
    return rows


def noniid_severity(fracs=(0.3, 0.8), iters=5, H=20):
    """IKC's edge over FedAvg should GROW with non-IID severity (the
    whole point of class-balanced scheduling)."""
    import jax
    from repro.core.hfl import (evaluate_in_batches, hfl_global_iteration,
                                pad_device_data)
    from repro.core.scheduling import (FedAvgScheduler, IKCScheduler,
                                       run_device_clustering)
    from repro.data import make_dataset, partition_noniid
    from repro.models import cnn

    out = {}
    for frac in fracs:
        X, y, Xt, yt = make_dataset("fmnist_syn", n_train=5000, n_test=800,
                                    seed=1)
        fed = partition_noniid(X, y, Xt, yt, n_devices=40,
                               size_range=(50, 90), majority_frac=frac,
                               seed=1)
        Xp, yp, mask = pad_device_data(fed)
        key = jax.random.PRNGKey(0)
        sp = cm.SystemParams(n_devices=40, n_edges=5)
        accs = {}
        for method in ("ikc", "fedavg"):
            if method == "ikc":
                mini = cnn.mini_init(key)
                crop = jax.vmap(cnn.mini_preprocess)(
                    Xp[:, :, :, :, :1], jax.random.split(key, 40))
                labels, _ = run_device_clustering(
                    key, cnn.mini_apply, mini, crop, yp, mask, 10, sp.L, 0.01)
                sched = IKCScheduler(labels, max(1, H // 10))
            else:
                sched = FedAvgScheduler(40, H)
            params = cnn.cnn_init(key, (28, 28), 1)
            rng = np.random.default_rng(0)
            acc = 0.0
            for i in range(iters):
                sel = np.asarray(sched.schedule(rng))
                assign = np.asarray(sel % sp.n_edges)
                params = hfl_global_iteration(
                    cnn.cnn_apply, params, Xp[sel], yp[sel], mask[sel],
                    np.asarray(fed.sizes[sel], np.float32), assign,
                    M=sp.n_edges, L=sp.L, Q=sp.Q, lr=0.03)
                acc = evaluate_in_batches(cnn.cnn_apply, params,
                                          fed.X_test, fed.y_test)
            accs[method] = float(acc)
        gap = accs["ikc"] - accs["fedavg"]
        out[frac] = {"ikc": accs["ikc"], "fedavg": accs["fedavg"],
                     "gap": gap}
        emit(f"ablation/noniid_{frac}", 0.0,
             f"ikc={accs['ikc']:.3f};fedavg={accs['fedavg']:.3f};gap={gap:+.3f}")
    with open("results/ablation_noniid.json", "w") as f:
        json.dump({str(k): v for k, v in out.items()}, f, indent=1)
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    lambda_sweep()
    noniid_severity()
