"""Fig. 5: D3QN learning curve (avg accumulated reward per 50 episodes)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core.cost_model import SystemParams
from repro.drl.train import D3QNTrainer


def run(episodes: int = 400, H: int = 20, out_json="results/fig5.json",
        engine: str = "batched"):
    sp = SystemParams(n_edges=5, lam=1.0)
    t0 = time.perf_counter()
    tr = D3QNTrainer(sp, H=H, hidden=128, hfel_transfer=40, hfel_exchange=80,
                     alloc_steps=60, minibatch=96,
                     eps_decay_episodes=episodes // 2, seed=0,
                     engine=engine)
    hist = tr.train(max_episodes=episodes, log_every=50, verbose=False)
    wall = time.perf_counter() - t0
    window = 50
    curve = [float(np.mean(hist[max(0, i - window):i + 1]))
             for i in range(len(hist))]
    os.makedirs("results", exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({"returns": hist, "smoothed": curve, "H": H}, f)
    early = float(np.mean(hist[:window]))
    late = float(np.mean(hist[-window:]))
    emit("fig5/d3qn_curve", wall * 1e6 / max(1, episodes),
         f"early_avg={early:+.1f};late_avg={late:+.1f};"
         f"improved={late > early + 2};max_possible={H}")
    return tr  # trained agent reused by fig6


if __name__ == "__main__":
    run()
