"""Micro-benchmark: whole-sweep-on-device scan vs the per-round engines.

Measures COMPLETE R-round sweeps (scheduling, assignment, training
rounds, eval, early-stop bookkeeping) end-to-end at S ∈ {8, 32, 128}
seed lanes through four engine variants:

* ``perround_host``  — the PR-1..4 loop: one ``sweep_round`` dispatch
  plus host scheduling/assignment/eval per round (``fused=False``).
* ``perround_shard`` — the PR-5 lane-sharded per-round loop
  (``shard=True, fused=False``): the prior state of the art.
* ``fused``          — ONE ``sweep_scan`` dispatch for the whole sweep
  (``fused=True``).
* ``fused_shard``    — the fused scan under ``shard_map``
  (``shard=True, fused=True``): still one dispatch, lane-parallel.

Engine dispatches are *counted*, not asserted from docs: the child
wraps the module-level jitted entry points (``sweep_round*``,
``sweep_scan*``, ``_sweep_eval``) with counters, so the JSON records
that the fused variants hit the engine exactly once per sweep while the
per-round paths pay R engine dispatches + R eval round-trips. The
headline claim gates the fused family's best lanes/sec at the largest S
against the per-round sharded path measured in the same child — the
fused scan runs the identical round compute, so it must not be slower
than the loop it replaces (the win is the removed per-round dispatch,
host sync and schedule/assign latency; biggest at small per-round
compute, modest at this allocation-heavy profile).

Workload: the ``bench_sweep_shard`` allocation-heavy sweep profile
(M=10 edges, H=8 cohort, 500 solver steps, minimal local training),
R=5 rounds, geo assignment. Because
``XLA_FLAGS=--xla_force_host_platform_device_count`` must be set before
jax import, measurement runs in a spawned ``--child`` process; the
parent validates the JSON and emits CSV.

    PYTHONPATH=src python -m benchmarks.bench_sweep_fused [--smoke]

``--smoke`` spawns a tiny 2-device child and asserts the four variants
run end-to-end, the fused dispatch count is exactly 1, and the JSON is
well-formed (CI guard, no timing claims).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LANES = (8, 32, 128)
N_EMU_DEVICES = 8
ALLOC_STEPS = 500
M_EDGES = 10
N_DEVICES = 40
H_COHORT = 8
ROUNDS = 5
REPEATS = 2


# --------------------------------------------------------------- child

def _count_engine_calls():
    """Wrap the jitted engine entry points with call counters.

    Returns the shared counts dict; keys are entry-point names. run()
    resolves these names from module globals at call time, so wrapping
    the module attributes observes every dispatch the runner makes.
    """
    import repro.core.sweep as sw

    counts = {}

    def wrap(name):
        orig = getattr(sw, name)

        def counted(*a, **k):
            counts[name] = counts.get(name, 0) + 1
            return orig(*a, **k)

        setattr(sw, name, counted)

    for name in ("sweep_round", "sweep_round_sharded", "sweep_scan",
                 "sweep_scan_sharded", "_sweep_eval"):
        wrap(name)
    return counts


def _measure(lanes, n_emu, *, n_devices, m_edges, h_cohort, alloc_steps,
             rounds, repeats, n_train, n_test):
    """Runs inside the forced-device-count child: time whole R-round
    sweeps through each engine variant at each lane count."""
    import jax
    import numpy as np

    from repro.core.sweep import SweepRunner, build_scheduler
    from repro.data import make_dataset, partition_noniid
    from repro.core.cost_model import SystemParams, sample_population

    assert len(jax.devices()) == n_emu, (
        f"child expected {n_emu} devices, got {len(jax.devices())}")
    counts = _count_engine_calls()
    sp = SystemParams(n_devices=n_devices, n_edges=m_edges, L=1, Q=1,
                      d_range=(1, 2))
    pop = sample_population(sp, seed=0)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                n_test=n_test, seed=0)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                           size_range=(1, 2), seed=0)

    out = {"config": {"M": m_edges, "N": n_devices, "H": h_cohort,
                      "alloc_steps": alloc_steps, "rounds": rounds,
                      "emulated_devices": n_emu,
                      "host_cores": os.cpu_count(),
                      "mode": "cpu-emulation"},
           "lanes": {}}
    variants = (("perround_host", False, False),
                ("perround_shard", True, False),
                ("fused", False, True),
                ("fused_shard", True, True))
    for S in lanes:
        row = {}
        for key, shard, fused in variants:
            runner = SweepRunner(sp, [(pop, fed)] * S, lr=0.02,
                                 alloc_steps=alloc_steps, model_seed=0,
                                 shard=shard)

            def call():
                scheds = [build_scheduler("fedavg", fed, sp, h_cohort,
                                          seed=s) for s in range(S)]
                res = runner.run(scheds, rounds, assign="geo",
                                 seeds=list(range(S)), fused=fused)
                np.asarray(res["acc"])          # sync
                return res

            call()                              # warmup / compile
            times, res = [], None
            for _ in range(repeats):
                counts.clear()
                t0 = time.perf_counter()
                res = call()
                times.append(time.perf_counter() - t0)
            dt = min(times)
            engine = sum(counts.get(k, 0)
                         for k in ("sweep_round", "sweep_round_sharded",
                                   "sweep_scan", "sweep_scan_sharded"))
            if fused:
                assert res["n_dispatches"] == engine == 1, (
                    key, res["n_dispatches"], counts)
            else:
                assert engine == rounds, (key, counts)
            row[f"{key}_sweep_ms"] = dt * 1e3
            row[f"{key}_sweep_mean_ms"] = sum(times) / len(times) * 1e3
            row[f"{key}_lanes_per_s"] = S / dt
            row[f"{key}_engine_dispatches"] = engine
            row[f"{key}_eval_dispatches"] = counts.get("_sweep_eval", 0)
        best_fused = max(row["fused_lanes_per_s"],
                         row["fused_shard_lanes_per_s"])
        row["fused_speedup_vs_perround_host"] = (
            best_fused / row["perround_host_lanes_per_s"])
        row["fused_speedup_vs_perround_shard"] = (
            best_fused / row["perround_shard_lanes_per_s"])
        out["lanes"][str(S)] = row
    return out


def _child_main(args):
    cfg = json.loads(args.config)
    result = _measure(tuple(cfg.pop("lanes")), cfg.pop("n_emu"), **cfg)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=1)


# -------------------------------------------------------------- parent

def _spawn(cfg: dict, n_emu: int) -> dict:
    from repro.utils import forced_device_env

    env = forced_device_env(
        n_emu, pythonpath=(os.path.join(REPO_ROOT, "src"), REPO_ROOT))
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
        out_path = tf.name
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_sweep_fused",
             "--child", "--out", out_path,
             "--config", json.dumps({**cfg, "n_emu": n_emu})],
            env=env, cwd=REPO_ROOT, capture_output=True, text=True,
            timeout=3600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sweep-fused child failed:\n{proc.stdout}\n{proc.stderr}")
        with open(out_path) as fh:
            return json.load(fh)
    finally:
        os.unlink(out_path)


def run(out_json: str = "BENCH_sweep_fused.json", lanes=LANES,
        n_emu: int = N_EMU_DEVICES, rounds: int = ROUNDS,
        check_claims: bool = True):
    from benchmarks.common import emit

    result = _spawn(dict(lanes=list(lanes), n_devices=N_DEVICES,
                         m_edges=M_EDGES, h_cohort=H_COHORT,
                         alloc_steps=ALLOC_STEPS, rounds=rounds,
                         repeats=REPEATS, n_train=120, n_test=20), n_emu)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)

    for S, row in result["lanes"].items():
        emit(f"sweep_fused/S{S}_perround",
             row["perround_host_sweep_ms"] * 1e3,
             f"lanes_per_s={row['perround_host_lanes_per_s']:.1f};"
             f"shard={row['perround_shard_lanes_per_s']:.1f};"
             f"dispatches={row['perround_host_engine_dispatches']}")
        emit(f"sweep_fused/S{S}_fused", row["fused_sweep_ms"] * 1e3,
             f"lanes_per_s={row['fused_lanes_per_s']:.1f};"
             f"shard={row['fused_shard_lanes_per_s']:.1f};"
             f"dispatches={row['fused_engine_dispatches']};"
             f"vs_perround_shard="
             f"{row['fused_speedup_vs_perround_shard']:.2f}x")
    if check_claims:
        s_hi = max(int(k) for k in result["lanes"])
        hi = result["lanes"][str(s_hi)]
        # same-compute replacement: tolerate 5% timer noise below 1.0x
        ok = hi["fused_speedup_vs_perround_shard"] >= 0.95
        result["claim_fused_not_slower"] = {
            "pass": bool(ok), "at_lanes": s_hi,
            "fused_speedup_vs_perround_shard":
                hi["fused_speedup_vs_perround_shard"],
            "fused_speedup_vs_perround_host":
                hi["fused_speedup_vs_perround_host"]}
        result["claim_single_dispatch"] = {
            "pass": hi["fused_engine_dispatches"] == 1, "at_lanes": s_hi,
            "fused_dispatches": hi["fused_engine_dispatches"],
            "perround_dispatches": hi["perround_host_engine_dispatches"]}
        with open(out_json, "w") as fh:
            json.dump(result, fh, indent=1)
        emit("sweep_fused/claim_fused_not_slower", 0.0,
             f"pass={ok};vs_perround_shard="
             f"{hi['fused_speedup_vs_perround_shard']:.2f}x;"
             f"vs_perround_host="
             f"{hi['fused_speedup_vs_perround_host']:.2f}x")
        emit("sweep_fused/claim_single_dispatch", 0.0,
             f"pass={hi['fused_engine_dispatches'] == 1};"
             f"fused={hi['fused_engine_dispatches']};"
             f"perround={hi['perround_host_engine_dispatches']}")
    return result


def run_smoke(out_json: str = "results/BENCH_sweep_fused_smoke.json"):
    """Tiny-shape CI guard: 2 emulated devices, asserts all four engine
    variants run end-to-end, the fused paths really are one dispatch
    (the child asserts the counter) and the JSON is well-formed."""
    from benchmarks.common import emit

    result = _spawn(dict(lanes=[2, 4], n_devices=8, m_edges=2, h_cohort=4,
                         alloc_steps=25, rounds=2, repeats=1, n_train=60,
                         n_test=20), 2)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["config"]["emulated_devices"] == 2
    for row in loaded["lanes"].values():
        assert row["fused_engine_dispatches"] == 1
        assert row["fused_shard_engine_dispatches"] == 1
        assert row["perround_host_engine_dispatches"] == 2
        assert all(row[f"{v}_sweep_ms"] > 0
                   for v in ("perround_host", "perround_shard", "fused",
                             "fused_shard"))
    emit("sweep_fused/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; assert-runs-and-emits-JSON only")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", help=argparse.SUPPRESS)
    ap.add_argument("--config", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        _child_main(args)
    elif args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
