"""Micro-benchmark: scheduling + clustering-cost scaling on the
population axis (ISSUE 6 tentpole; ROADMAP "millions of IoT users").

Sweeps N ∈ {1e3, 1e4, 1e5} devices at a FIXED cohort (K=10 clusters,
h=10 → H=100) and times, per N:

* per-round ``schedule()`` for the vectorized FedAvg/VKC/IKC state
  machines (median over rounds) — the O(scheduled) claim is
  ``sublinear_10x``: N=1e5 within 10x of N=1e3 for a fixed cohort;
* the serial list-based oracles (capped at ``serial_max_n`` — they are
  O(N) per round, which is the point);
* the jitted segment-program ``clustering_cost`` and the gather +
  segment-sum cohort ``round_cost`` evaluation (both O(H) post-compile);
* ``adjusted_rand_index`` at full N (int64-overflow regression scale);
* K-means distance passes, Pallas kernel (interpret on CPU) vs the jnp
  oracle, plus one full K-means fit on the Table-I device features.

It then reruns the paper's headline scheduling-ratio experiment
(Figs. 3-4 read 50%/30% scheduling suffices) at N=1e5 on the COST side:
devices are K-means-clustered on their cost-model features, IKC
schedules ratio*N of them, and the round delay / energy / uplink
message volume are evaluated against the ratio=1.0 cohort. (CNN
convergence at N=1e5 is not reachable on this container; the
delay/energy/message savings are the half of the claim that scales.)

    PYTHONPATH=src python -m benchmarks.bench_schedule_scale [--smoke]

``--smoke`` keeps the full N sweep — the CI guard's job is exactly
"N=1e5 rounds complete without O(N) host loops" — but trims repeat
counts and the interpret-mode kernel shape; JSON under ``results/``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

N_SWEEP = (1_000, 10_000, 100_000)
K_CLUSTERS = 10
H_COHORT = 100                   # h=10 per cluster, fixed across N
ROUNDS = 30
SERIAL_ROUNDS = 5
SERIAL_MAX_N = 10_000            # serial oracles are O(N)/round; cap them
RATIOS = (0.3, 0.5, 1.0)
SUBLINEAR_GATE = 10.0            # N=1e5 within 10x of N=1e3


def _median_round_s(sched, rng, rounds: int) -> float:
    import numpy as np
    for _ in range(2):                                   # warm the state
        sched.schedule(rng)
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        sched.schedule(rng)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _labels(rng, n: int, k: int):
    lab = rng.integers(0, k, n)
    lab[:k] = range(k)                                   # no empty clusters
    return lab


def _cohort_cost(sp, pop, sched_idx):
    """Nearest-edge assignment + uniform bandwidth share + (13)/(14) on
    the scheduled subset only — gather + segment ops, O(H)."""
    import jax.numpy as jnp

    from repro.core import cost_model as cm

    g_sel_all = pop.g[sched_idx]                         # (H, M)
    assign = jnp.argmax(g_sel_all, axis=1)
    counts = jnp.bincount(assign, length=pop.n_edges)
    b = pop.B_m[assign] / jnp.maximum(counts[assign], 1)
    f = pop.f_max[sched_idx]
    T_i, E_i, _, _ = cm.round_cost(sp, pop, sched_idx, assign, b, f)
    return float(T_i), float(E_i)


def _measure(n_sweep, rounds, serial_rounds, serial_max_n, kernel_np,
             ratio_rounds):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.clustering import (adjusted_rand_index, kmeans,
                                       pairwise_sq_dists)
    from repro.core.cost_model import SystemParams, sample_population
    from repro.core.scheduling.device_clustering import clustering_cost
    from repro.core.scheduling.schedulers import (
        FedAvgScheduler, IKCScheduler, SerialFedAvgScheduler,
        SerialIKCScheduler, SerialVKCScheduler, VKCScheduler)

    out = {"config": {"K": K_CLUSTERS, "H": H_COHORT, "rounds": rounds,
                      "serial_max_n": serial_max_n,
                      "host_cores": os.cpu_count()},
           "scale": {}}
    h = H_COHORT // K_CLUSTERS
    for n in n_sweep:
        rng = np.random.default_rng(0)
        sp = SystemParams(n_devices=n, n_edges=5)
        pop = sample_population(sp, seed=0)
        lab = _labels(rng, n, K_CLUSTERS)
        row = {}
        engines = {"fedavg": FedAvgScheduler(n, H_COHORT),
                   "vkc": VKCScheduler(lab, h),
                   "ikc": IKCScheduler(lab, h)}
        for name, s in engines.items():
            row[f"{name}_round_ms"] = _median_round_s(s, rng, rounds) * 1e3
        if n <= serial_max_n:
            serials = {"fedavg": SerialFedAvgScheduler(n, H_COHORT),
                       "vkc": SerialVKCScheduler(lab, h),
                       "ikc": SerialIKCScheduler(lab, h)}
            for name, s in serials.items():
                row[f"{name}_serial_round_ms"] = (
                    _median_round_s(s, rng, serial_rounds) * 1e3)
        # jitted Alg.-2 pricing: time the steady-state call
        clustering_cost(sp, pop, aux_bits=1e5)           # compile
        t0 = time.perf_counter()
        delay, energy = clustering_cost(sp, pop, aux_bits=1e5)
        row["clustering_cost_ms"] = (time.perf_counter() - t0) * 1e3
        row["clustering_delay_model"] = delay
        # cohort round-cost evaluation on the scheduled subset (O(H))
        sched_idx = jnp.asarray(engines["ikc"].schedule(rng))
        _cohort_cost(sp, pop, sched_idx)                 # compile
        t0 = time.perf_counter()
        _cohort_cost(sp, pop, sched_idx)
        row["round_cost_ms"] = (time.perf_counter() - t0) * 1e3
        # ARI at full N (the int64-overflow satellite's scale)
        noisy = np.where(rng.random(n) < 0.2,
                         rng.integers(0, K_CLUSTERS, n), lab)
        t0 = time.perf_counter()
        ari = adjusted_rand_index(noisy, lab)
        row["ari_ms"] = (time.perf_counter() - t0) * 1e3
        row["ari_value"] = float(ari)
        assert -0.5 <= ari <= 1.0, ari                   # overflow guard
        out["scale"][str(n)] = row

    # sublinearity claim: fixed cohort => N=1e5 within 10x of N=1e3
    lo, hi = str(min(n_sweep)), str(max(n_sweep))
    ratios = {name: (out["scale"][hi][f"{name}_round_ms"] /
                     max(out["scale"][lo][f"{name}_round_ms"], 1e-6))
              for name in ("fedavg", "vkc", "ikc")}
    out["schedule_scale_ratio"] = ratios
    out["claim_sublinear_10x"] = bool(
        max(ratios.values()) <= SUBLINEAR_GATE)

    # K-means distance pass: Pallas kernel (interpret on CPU) vs jnp
    kn, kp = kernel_np
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (kn, kp), jnp.float32)
    c = jax.random.normal(key, (K_CLUSTERS, kp), jnp.float32)
    for use_kernel in (False, True):
        tag = "kernel" if use_kernel else "jnp"
        jax.block_until_ready(pairwise_sq_dists(x, c, use_kernel=use_kernel))
        t0 = time.perf_counter()
        jax.block_until_ready(pairwise_sq_dists(x, c, use_kernel=use_kernel))
        out[f"pairwise_{tag}_ms"] = (time.perf_counter() - t0) * 1e3
    out["pairwise_shape"] = [kn, kp]

    # one full K-means fit on Table-I device features at the largest N
    n_big = max(n_sweep)
    sp = SystemParams(n_devices=n_big, n_edges=5)
    pop = sample_population(sp, seed=0)
    feats = pop.features()
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-8)
    lab_big, _ = kmeans(jax.random.PRNGKey(1), feats, K_CLUSTERS, iters=10)
    jax.block_until_ready(lab_big)
    t0 = time.perf_counter()
    lab_big, _ = kmeans(jax.random.PRNGKey(1), feats, K_CLUSTERS, iters=10)
    jax.block_until_ready(lab_big)
    out["kmeans_fit_ms"] = (time.perf_counter() - t0) * 1e3
    lab_big = np.asarray(lab_big)

    # scheduling-ratio rerun at N=1e5 (cost side of Figs. 3-4): IKC over
    # the K-means clusters, delay/energy/message volume vs full
    # scheduling. lab_big can leave clusters empty (K' < K) — exactly
    # the short-cohort path the sweep engine tops up.
    rr = {}
    base = None
    rng = np.random.default_rng(1)
    for ratio in RATIOS:
        H = int(ratio * n_big)
        s = IKCScheduler(lab_big, max(1, H // K_CLUSTERS))
        times = []
        # median of >= 3 rounds even in smoke: a single 50k-cohort draw is
        # too noisy for the --check 2x regression gate
        for _ in range(max(3, ratio_rounds)):
            t0 = time.perf_counter()
            sched_idx = s.schedule(rng)
            times.append(time.perf_counter() - t0)
        T_i, E_i = _cohort_cost(sp, pop, jnp.asarray(sched_idx))
        row = {"H": len(sched_idx),
               "schedule_round_ms": float(np.median(times)) * 1e3,
               "T_round": T_i, "E_round_j": E_i,
               "message_gbits": len(sched_idx) * sp.model_bits / 1e9}
        if ratio == 1.0:
            base = row
        rr[f"{ratio:.0%}"] = row
    for row in rr.values():
        row["energy_saving_vs_full"] = 1.0 - row["E_round_j"] / base["E_round_j"]
        row["message_saving_vs_full"] = (
            1.0 - row["message_gbits"] / base["message_gbits"])
    out["ratio_rerun_n100k"] = rr
    return out


def _emit(result):
    from benchmarks.common import emit

    for n, row in result["scale"].items():
        serial = row.get("ikc_serial_round_ms")
        emit(f"schedule_scale/N{n}", row["ikc_round_ms"] * 1e3,
             f"fedavg_ms={row['fedavg_round_ms']:.3f};"
             f"vkc_ms={row['vkc_round_ms']:.3f};"
             f"ikc_serial_ms={serial if serial is None else round(serial, 3)};"
             f"clustering_cost_ms={row['clustering_cost_ms']:.2f};"
             f"round_cost_ms={row['round_cost_ms']:.2f};"
             f"ari_ms={row['ari_ms']:.1f}")
    r = result["schedule_scale_ratio"]
    emit("schedule_scale/claim_sublinear_10x", 0.0,
         f"pass={result['claim_sublinear_10x']};"
         + ";".join(f"{k}={v:.2f}x" for k, v in r.items()))
    for ratio, row in result["ratio_rerun_n100k"].items():
        emit(f"schedule_scale/ratio_{ratio}", row["schedule_round_ms"] * 1e3,
             f"T_round={row['T_round']:.2f}s;E_round={row['E_round_j']:.0f}J;"
             f"msg={row['message_gbits']:.1f}Gb;"
             f"E_saving={row['energy_saving_vs_full']:.0%};"
             f"msg_saving={row['message_saving_vs_full']:.0%}")


def run(out_json: str = "BENCH_schedule_scale.json"):
    result = _measure(N_SWEEP, ROUNDS, SERIAL_ROUNDS, SERIAL_MAX_N,
                      kernel_np=(1024, 512), ratio_rounds=3)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    _emit(result)
    assert result["claim_sublinear_10x"], result["schedule_scale_ratio"]
    return result


def run_smoke(out_json: str = "results/BENCH_schedule_scale_smoke.json"):
    """CI guard: the FULL N sweep (the whole point is that N=1e5 rounds
    complete without O(N) host loops) at trimmed repeat counts."""
    from benchmarks.common import emit

    result = _measure(N_SWEEP, rounds=5, serial_rounds=2,
                      serial_max_n=1_000, kernel_np=(256, 512),
                      ratio_rounds=1)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as fh:
        json.dump(result, fh, indent=1)
    with open(out_json) as fh:
        loaded = json.load(fh)
    assert loaded["claim_sublinear_10x"], loaded["schedule_scale_ratio"]
    assert str(max(N_SWEEP)) in loaded["scale"]
    _emit(result)
    emit("schedule_scale/smoke", 0.0, "pass=True")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="full N sweep at trimmed repeats; JSON under "
                         "results/")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run()


if __name__ == "__main__":
    main()
