#!/usr/bin/env python
"""Docs CI guard: link integrity + executable fenced python snippets.

    PYTHONPATH=src python tools/check_docs.py [--no-exec] [FILES...]

Over ``README.md``, ``docs/*.md`` and ``benchmarks/README.md`` (or an
explicit file list):

* **Links** — every relative markdown link / image target must exist on
  disk (anchors are stripped; ``http(s):``/``mailto:`` externals and
  the README's relative CI-badge route are skipped — CI must stay
  offline-deterministic).
* **Snippets** — every fenced code block tagged exactly ``python`` is
  executed with the repo on ``PYTHONPATH`` (cwd = repo root, a temp dir
  for scratch); a snippet that raises fails the job. Blocks tagged
  ``python no-run`` are skipped — use that for illustrative fragments
  that aren't self-contained — and everything else (``bash``, ``text``,
  untagged) is ignored.

Exit code 0 iff all links resolve and all snippets run.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) and ![alt](target); ignores in-snippet indexing like
# x[0](...) by requiring the target not to start with a quote/paren
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```(.*?)\s*$")


def default_files() -> list[str]:
    files = [os.path.join(REPO, "README.md"),
             os.path.join(REPO, "benchmarks", "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def iter_fences(text: str):
    """Yield (info_string, body, start_line) for each fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE_RE.match(lines[i])
        if m and lines[i].startswith("```") and m.group(1) != "":
            info, start = m.group(1).strip(), i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield info, "\n".join(body), start
        i += 1


def check_links(path: str) -> list[str]:
    errs = []
    text = open(path).read()
    # strip fenced blocks so code like `a[0](b)` never parses as a link
    stripped = []
    in_fence = False
    for ln in text.splitlines():
        if ln.startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            stripped.append(ln)
    for target in _LINK_RE.findall("\n".join(stripped)):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        if "/actions/" in target:      # the README's relative badge route
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errs.append(f"{os.path.relpath(path, REPO)}: dead link "
                        f"-> {target}")
    return errs


def run_snippets(path: str) -> list[str]:
    errs = []
    text = open(path).read()
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    for info, body, line in iter_fences(text):
        if info != "python":
            continue
        name = f"{os.path.relpath(path, REPO)}:{line}"
        with tempfile.TemporaryDirectory() as tmp:
            snip = os.path.join(tmp, "snippet.py")
            with open(snip, "w") as fh:
                fh.write(body + "\n")
            print(f"[check_docs] exec {name}", flush=True)
            proc = subprocess.run([sys.executable, snip], cwd=REPO,
                                  env=env, capture_output=True, text=True,
                                  timeout=600)
        if proc.returncode != 0:
            errs.append(f"{name}: snippet failed "
                        f"(exit {proc.returncode})\n{proc.stdout}"
                        f"{proc.stderr}")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="markdown files (default: README.md, docs/*.md, "
                         "benchmarks/README.md)")
    ap.add_argument("--no-exec", action="store_true",
                    help="links only; skip snippet execution")
    args = ap.parse_args()

    files = [os.path.abspath(f) for f in args.files] or default_files()
    errs = []
    n_snips = 0
    for path in files:
        errs += check_links(path)
        if not args.no_exec:
            n_snips += sum(1 for info, _, _ in
                           iter_fences(open(path).read())
                           if info == "python")
            errs += run_snippets(path)
    for e in errs:
        print(f"[check_docs] FAIL {e}", file=sys.stderr, flush=True)
    print(f"[check_docs] {len(files)} files, {n_snips} executable "
          f"snippets, {len(errs)} errors", flush=True)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
