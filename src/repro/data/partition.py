"""Non-IID federated partitioning (majority-class skew, paper §IV-A)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class FederatedData:
    """Per-device datasets + ground-truth majority classes (for ARI)."""
    X: List[np.ndarray]
    y: List[np.ndarray]
    majority_class: np.ndarray        # (N,) int — clustering ground truth
    X_test: np.ndarray
    y_test: np.ndarray
    n_classes: int

    @property
    def n_devices(self) -> int:
        return len(self.X)

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(y) for y in self.y])


def partition_noniid(X: np.ndarray, y: np.ndarray, X_test, y_test,
                     n_devices: int, size_range: Tuple[int, int],
                     majority_frac: float = 0.8, n_classes: int = 10,
                     seed: int = 0,
                     majority_assignment: Optional[np.ndarray] = None
                     ) -> FederatedData:
    """Each device n holds D_n ~ U[size_range] samples, `majority_frac` of
    which come from a single majority class (round-robin over classes so
    every class has ~N/K majority devices), the rest drawn uniformly."""
    rng = np.random.default_rng(seed)
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    if majority_assignment is None:
        majority_assignment = np.arange(n_devices) % n_classes
        rng.shuffle(majority_assignment)
    Xs, ys = [], []
    for n in range(n_devices):
        D_n = int(rng.integers(size_range[0], size_range[1] + 1))
        c = int(majority_assignment[n])
        n_major = int(round(majority_frac * D_n))
        idx_major = rng.choice(by_class[c], n_major, replace=True)
        idx_rest = rng.integers(0, len(y), D_n - n_major)
        idx = np.concatenate([idx_major, idx_rest])
        rng.shuffle(idx)
        Xs.append(X[idx])
        ys.append(y[idx])
    return FederatedData(Xs, ys, majority_assignment.astype(np.int32),
                         X_test, y_test, n_classes)
