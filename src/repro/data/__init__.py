from repro.data.synthetic import SyntheticSpec, make_dataset, DATASETS  # noqa: F401
from repro.data.synthetic import SeqSpec, make_seq_dataset, SEQ_DATASETS  # noqa: F401
from repro.data.partition import FederatedData, partition_noniid  # noqa: F401
from repro.data.pipeline import batch_iterator, sample_batch  # noqa: F401
