"""Class-structured synthetic datasets (offline stand-ins).

Images (``make_dataset``): each class k has a smooth random prototype
image; a sample is ``clip(prototype + pixel noise + global brightness
jitter, 0, 1)``. Sequences (``make_seq_dataset``): each class k has a
random token distribution over the vocabulary; a sample is ``seq_len``
i.i.d. tokens from that distribution. Both preserve the two properties
the paper's experiments rely on:
  1. classes are learnably separable by a small model (accuracy curves
     move — for sequences, the class token-frequency profile is linearly
     separable from a mean-pooled embedding),
  2. models locally trained on a majority class have weights that cluster
     by that class (so K-means on auxiliary-model weights recovers the
     majority class; ARI is measurable exactly as in Table II).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    image_hw: Tuple[int, int]
    channels: int
    n_classes: int = 10
    noise: float = 0.35
    proto_smooth: int = 3       # prototype low-frequency scale


DATASETS = {
    "fmnist_syn": SyntheticSpec("fmnist_syn", (28, 28), 1),
    "cifar_syn": SyntheticSpec("cifar_syn", (32, 32), 3),
}


def _smooth(rng: np.random.Generator, hw, channels, k: int) -> np.ndarray:
    """Low-frequency random image in [0,1]: upsampled coarse noise."""
    H, W = hw
    coarse = rng.random((k + 2, k + 2, channels))
    ys = np.linspace(0, k + 1, H)
    xs = np.linspace(0, k + 1, W)
    yi, xi = np.floor(ys).astype(int), np.floor(xs).astype(int)
    yf, xf = ys - yi, xs - xi
    yi1 = np.minimum(yi + 1, k + 1)
    xi1 = np.minimum(xi + 1, k + 1)
    a = coarse[yi][:, xi] * (1 - yf)[:, None, None] + coarse[yi1][:, xi] * yf[:, None, None]
    b = coarse[yi][:, xi1] * (1 - yf)[:, None, None] + coarse[yi1][:, xi1] * yf[:, None, None]
    img = a * (1 - xf)[None, :, None] + b * xf[None, :, None]
    return img


def class_prototypes(spec: SyntheticSpec, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([_smooth(rng, spec.image_hw, spec.channels, spec.proto_smooth)
                     for _ in range(spec.n_classes)])


def make_dataset(name: str, n_train: int = 20_000, n_test: int = 2_000,
                 seed: int = 0):
    """Returns (X_train, y_train, X_test, y_test), images NHWC f32 in [0,1]."""
    spec = DATASETS[name]
    protos = class_prototypes(spec, seed)
    rng = np.random.default_rng(seed + 1)

    def draw(n):
        y = rng.integers(0, spec.n_classes, n)
        noise = rng.normal(0, spec.noise, (n, *spec.image_hw, spec.channels))
        bright = rng.normal(0, 0.08, (n, 1, 1, 1))
        X = np.clip(protos[y] + noise + bright, 0.0, 1.0).astype(np.float32)
        return X, y.astype(np.int32)

    X_tr, y_tr = draw(n_train)
    X_te, y_te = draw(n_test)
    return X_tr, y_tr, X_te, y_te


@dataclasses.dataclass(frozen=True)
class SeqSpec:
    """Synthetic sequence-classification task for the model-zoo payloads.

    vocab_size defaults to 257 — at most the smallest smoke-config vocab
    across the registry archs, so one dataset feeds every arch's
    embedding table; seq_len 16 is a multiple of the mamba2 smoke SSD
    chunk (SSM archs require ``seq_len % ssm.chunk == 0``).
    """
    name: str
    seq_len: int = 16
    vocab_size: int = 257
    n_classes: int = 10
    sharpness: float = 2.0      # spread of the per-class token logits


SEQ_DATASETS = {
    "seqcls_syn": SeqSpec("seqcls_syn"),
}


def class_token_dists(spec: SeqSpec, seed: int = 0) -> np.ndarray:
    """(n_classes, vocab) token distributions, one per class."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(0.0, spec.sharpness,
                        (spec.n_classes, spec.vocab_size))
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    return p / p.sum(axis=1, keepdims=True)


def make_seq_dataset(name: str = "seqcls_syn", n_train: int = 4096,
                     n_test: int = 512, seed: int = 0, *,
                     seq_len: int | None = None,
                     vocab_size: int | None = None,
                     n_classes: int | None = None):
    """Returns (X_train, y_train, X_test, y_test); X int32 (n, seq_len)."""
    spec = SEQ_DATASETS[name]
    if seq_len or vocab_size or n_classes:
        spec = dataclasses.replace(
            spec, seq_len=seq_len or spec.seq_len,
            vocab_size=vocab_size or spec.vocab_size,
            n_classes=n_classes or spec.n_classes)
    cdf = class_token_dists(spec, seed).cumsum(axis=1)
    rng = np.random.default_rng(seed + 1)

    def draw(n):
        y = rng.integers(0, spec.n_classes, n)
        u = rng.random((n, spec.seq_len))
        # inverse-CDF sampling against each sample's class distribution
        X = (u[:, :, None] >= cdf[y][:, None, :]).sum(axis=2)
        return np.minimum(X, spec.vocab_size - 1).astype(np.int32), \
            y.astype(np.int32)

    X_tr, y_tr = draw(n_train)
    X_te, y_te = draw(n_test)
    return X_tr, y_tr, X_te, y_te
