"""Class-structured synthetic image datasets (offline FashionMNIST/CIFAR-10
stand-ins).

Each class k has a smooth random prototype image; a sample is
``clip(prototype + pixel noise + global brightness jitter, 0, 1)``.
This preserves the two properties the paper's experiments rely on:
  1. classes are learnably separable by a small CNN (accuracy curves move),
  2. models locally trained on a majority class have weights that cluster
     by that class (so K-means on auxiliary-model weights recovers the
     majority class; ARI is measurable exactly as in Table II).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    image_hw: Tuple[int, int]
    channels: int
    n_classes: int = 10
    noise: float = 0.35
    proto_smooth: int = 3       # prototype low-frequency scale


DATASETS = {
    "fmnist_syn": SyntheticSpec("fmnist_syn", (28, 28), 1),
    "cifar_syn": SyntheticSpec("cifar_syn", (32, 32), 3),
}


def _smooth(rng: np.random.Generator, hw, channels, k: int) -> np.ndarray:
    """Low-frequency random image in [0,1]: upsampled coarse noise."""
    H, W = hw
    coarse = rng.random((k + 2, k + 2, channels))
    ys = np.linspace(0, k + 1, H)
    xs = np.linspace(0, k + 1, W)
    yi, xi = np.floor(ys).astype(int), np.floor(xs).astype(int)
    yf, xf = ys - yi, xs - xi
    yi1 = np.minimum(yi + 1, k + 1)
    xi1 = np.minimum(xi + 1, k + 1)
    a = coarse[yi][:, xi] * (1 - yf)[:, None, None] + coarse[yi1][:, xi] * yf[:, None, None]
    b = coarse[yi][:, xi1] * (1 - yf)[:, None, None] + coarse[yi1][:, xi1] * yf[:, None, None]
    img = a * (1 - xf)[None, :, None] + b * xf[None, :, None]
    return img


def class_prototypes(spec: SyntheticSpec, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([_smooth(rng, spec.image_hw, spec.channels, spec.proto_smooth)
                     for _ in range(spec.n_classes)])


def make_dataset(name: str, n_train: int = 20_000, n_test: int = 2_000,
                 seed: int = 0):
    """Returns (X_train, y_train, X_test, y_test), images NHWC f32 in [0,1]."""
    spec = DATASETS[name]
    protos = class_prototypes(spec, seed)
    rng = np.random.default_rng(seed + 1)

    def draw(n):
        y = rng.integers(0, spec.n_classes, n)
        noise = rng.normal(0, spec.noise, (n, *spec.image_hw, spec.channels))
        bright = rng.normal(0, 0.08, (n, 1, 1, 1))
        X = np.clip(protos[y] + noise + bright, 0.0, 1.0).astype(np.float32)
        return X, y.astype(np.int32)

    X_tr, y_tr = draw(n_train)
    X_te, y_te = draw(n_test)
    return X_tr, y_tr, X_te, y_te
