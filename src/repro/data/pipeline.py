"""Batching pipeline for the HFL trainer and the big-model trainer."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def batch_iterator(X: np.ndarray, y: np.ndarray, batch_size: int,
                   seed: int = 0, drop_last: bool = False
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Infinite shuffled epochs."""
    rng = np.random.default_rng(seed)
    n = len(y)
    while True:
        order = rng.permutation(n)
        for i in range(0, n, batch_size):
            sel = order[i:i + batch_size]
            if drop_last and len(sel) < batch_size:
                break
            yield X[sel], y[sel]


def sample_batch(X: np.ndarray, y: np.ndarray, batch_size: int,
                 rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    """IID sample with replacement (local SGD step, eq. (1))."""
    idx = rng.integers(0, len(y), batch_size)
    return X[idx], y[idx]


def token_batch_iterator(vocab: int, batch: int, seq: int, seed: int = 0):
    """Synthetic LM token stream for the big-arch example trainer:
    structured (Zipf-ish bigram) so loss can actually go down."""
    rng = np.random.default_rng(seed)
    # random sparse bigram transition table
    next_tok = rng.integers(0, vocab, size=(vocab, 4))
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        choice = rng.integers(0, 4, size=(batch, seq))
        noise = rng.random((batch, seq)) < 0.1
        rand = rng.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            nxt = next_tok[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
