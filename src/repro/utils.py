"""Shared small utilities: pytree math, rng splitting, shape helpers."""
from __future__ import annotations

import functools
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha * x + y, leaf-wise."""
    return jax.tree.map(lambda a, b: alpha * a + b, x, y)


def tree_weighted_sum(trees: Iterable[Pytree], weights) -> Pytree:
    """sum_i w_i * tree_i (weights need not be normalised)."""
    trees = list(trees)
    weights = list(weights)
    assert len(trees) == len(weights) and trees, "empty weighted sum"
    out = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = tree_axpy(w, t, out)
    return out


def tree_dot(a: Pytree, b: Pytree):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return functools.reduce(jnp.add, jax.tree.leaves(leaves))


def tree_norm(tree: Pytree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_size(tree: Pytree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_flatten_to_vector(tree: Pytree) -> jnp.ndarray:
    """Concatenate all leaves into a single f32 vector (for clustering)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, multiple: int) -> int:
    return ceil_div(x, multiple) * multiple


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024 or unit == "PiB":
            return f"{n:.2f} {unit}"
        n /= 1024
    return f"{n:.2f} PiB"


def human_flops(n: float) -> str:
    for unit in ("FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP", "PFLOP", "EFLOP"):
        if abs(n) < 1000 or unit == "EFLOP":
            return f"{n:.2f} {unit}"
        n /= 1000
    return f"{n:.2f} EFLOP"


def dbm_to_watt(dbm: float) -> float:
    return 10 ** (dbm / 10.0) / 1000.0


def db_to_linear(db) -> float:
    return 10 ** (db / 10.0)


def stable_hash(s: str) -> int:
    """Deterministic (non-salted) string hash for seeding."""
    h = 2166136261
    for c in s.encode():
        h = (h ^ c) * 16777619 & 0xFFFFFFFF
    return h


def forced_device_env(n_devices: int, pythonpath=()) -> dict:
    """Child-process env for N emulated host devices.

    ``--xla_force_host_platform_device_count`` only takes effect before
    jax import, so multi-device CPU work runs in spawned children — this
    builds their env in ONE place (the ``multidevice`` test fixture and
    ``benchmarks/bench_sweep_shard`` both use it): any pre-existing
    device-count flag in the inherited XLA_FLAGS is stripped (last-flag
    -wins would otherwise depend on the caller's environment), the CPU
    platform is pinned, and ``pythonpath`` entries are prepended.
    """
    import os

    env = os.environ.copy()
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n_devices}"])
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [*pythonpath] + ([env["PYTHONPATH"]]
                         if env.get("PYTHONPATH") else []))
    return env
