"""Episode-granular replay buffer Ω — device-resident array-backed ring.

Tuples (s_t, a_t, r_t, s_{t+1}) of one episode share the same feature
sequence, so the buffer stores per-episode (features, actions, rewards)
and samples minibatches of O tuples as (episode, slot) pairs — the BiLSTM
encodings are then computed once per sampled episode, not per tuple.

Storage is three preallocated jax arrays (``(capacity, H, F)`` features,
``(capacity, H)`` actions/rewards) allocated on the first push and kept
ON DEVICE for the buffer's whole life: ``push_batch`` inserts a wave of E
episodes with one jitted scatter (``.at[slots].set``) and
``sample``/``sample_updates`` build minibatches with one jitted gather,
so the trainer's update wave consumes replay slices without the features
ever round-tripping through host memory. Only the ring *counters* and
the sampling rng live on the host: ``sample_updates`` draws its
(episode, slot) indices from the caller's ``np.random.Generator`` with
exactly the same three vectorised calls as the original host-side ring —
rng-stream-compatible by construction — and ships the index arrays into
the gather.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _ring_write(feats_buf, actions_buf, rewards_buf, slots, feats, actions,
                rewards):
    """Scatter one E-episode wave into the ring slots (donated-in-place
    by XLA when the caller drops its old references)."""
    return (feats_buf.at[slots].set(feats),
            actions_buf.at[slots].set(actions),
            rewards_buf.at[slots].set(rewards))


@jax.jit
def _gather_updates(feats_buf, actions_buf, rewards_buf, eps, rows, slots):
    """Gather U stacked minibatches from the resident buffers: episode
    stacks (U, n_ep, H, F) plus per-tuple actions/rewards (U, n)."""
    return (feats_buf[eps], actions_buf[rows, slots],
            rewards_buf[rows, slots])


class EpisodeReplay:
    """Device-resident episode ring Ω (see module docstring).

    Episode arrays live on device from first push; host state is just
    the ring counters (``_n``, ``_pos``) and the caller-owned sampling
    rng. Episode shape (H, F) is fixed at first push — a mismatched
    push raises rather than silently re-padding.
    """

    def __init__(self, capacity_episodes: int = 2000):
        self.capacity = capacity_episodes
        self._feats: jax.Array | None = None      # (cap, H, F) device
        self._actions: jax.Array | None = None    # (cap, H) device
        self._rewards: jax.Array | None = None    # (cap, H) device
        self._n = 0        # episodes currently held (<= capacity)
        self._pos = 0      # next ring write slot

    def _ensure(self, H: int, F: int) -> None:
        if self._feats is None:
            self._feats = jnp.zeros((self.capacity, H, F), jnp.float32)
            self._actions = jnp.zeros((self.capacity, H), jnp.int32)
            self._rewards = jnp.zeros((self.capacity, H), jnp.float32)
        elif self._feats.shape[1:] != (H, F):
            raise ValueError(
                f"episode shape {(H, F)} != buffer {self._feats.shape[1:]}")

    @property
    def H(self) -> int:
        return 0 if self._feats is None else self._feats.shape[1]

    def push(self, feats, actions, rewards) -> None:
        """Insert one episode: feats (H, F), actions/rewards (H,)."""
        self.push_batch(np.asarray(feats)[None], np.asarray(actions)[None],
                        np.asarray(rewards)[None])

    def push_batch(self, feats, actions, rewards) -> None:
        """Insert a wave of E episodes in one jitted ring write.

        feats (E, H, F), actions/rewards (E, H) — numpy or device
        arrays; a batched trainer handing over device-resident
        ``_act_wave`` outputs incurs no host copy. If E exceeds the
        capacity only the most recent ``capacity`` episodes land (ring
        semantics of pushing them one at a time).
        """
        feats = jnp.asarray(feats, jnp.float32)
        E, H, F = feats.shape
        self._ensure(H, F)
        actions = jnp.asarray(actions, jnp.int32)
        rewards = jnp.asarray(rewards, jnp.float32)
        if E > self.capacity:       # only the tail survives a full lap
            feats = feats[-self.capacity:]
            actions = actions[-self.capacity:]
            rewards = rewards[-self.capacity:]
            self._pos = (self._pos + E) % self.capacity
            E = self.capacity
        slots = jnp.asarray((self._pos + np.arange(E)) % self.capacity)
        self._feats, self._actions, self._rewards = _ring_write(
            self._feats, self._actions, self._rewards, slots, feats,
            actions, rewards)
        self._pos = (self._pos + E) % self.capacity
        self._n = min(self._n + E, self.capacity)

    def __len__(self) -> int:
        """Total stored tuples (episodes x slots)."""
        return self._n * self.H

    @property
    def n_episodes(self) -> int:
        return self._n

    def sample(self, rng: np.random.Generator, n_tuples: int,
               max_episodes: int = 8
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                          jax.Array]:
        """One minibatch of ~n_tuples (episode, slot) pairs.

        Returns ``(feats, ep_idx, slots, actions, rewards)``: feats
        (n_ep, H, F) holds the n_ep <= max_episodes sampled episodes
        once each; ep_idx/slots (n,) index tuples into that stack;
        actions/rewards (n,) are the gathered per-tuple values.
        """
        feats, ep_idx, slots, actions, rewards = self.sample_updates(
            rng, 1, n_tuples, max_episodes=max_episodes)
        return feats[0], ep_idx[0], slots[0], actions[0], rewards[0]

    def sample_updates(self, rng: np.random.Generator, n_updates: int,
                       n_tuples: int, max_episodes: int = 8
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array, jax.Array]:
        """U independent minibatches, stacked for a scanned update wave.

        Returns ``(feats, ep_idx, slots, actions, rewards)`` with a
        leading (U,) axis on every array — feats (U, n_ep, H, F), the
        rest (U, n) — as device arrays ready to be consumed one slice
        per ``lax.scan`` step by the batched trainer with no host
        round-trip. All U draws happen in three vectorised host rng
        calls (episode choice via argsorted uniforms — without-
        replacement per update — plus one slot and one episode index
        draw); the resulting indices drive ONE jitted buffer gather.
        """
        if self._n == 0:
            raise ValueError("cannot sample from an empty replay buffer")
        U = n_updates
        H = self.H
        n_ep = min(max_episodes, self._n)
        per = max(1, n_tuples // n_ep)
        # (U, n_ep) distinct episode ids per update
        eps = np.argsort(rng.random((U, self._n)), axis=1)[:, :n_ep]
        slots = rng.integers(0, H, (U, n_ep * per))
        ep_idx = np.repeat(np.arange(n_ep)[None], U, axis=0)
        ep_idx = np.repeat(ep_idx, per, axis=1)               # (U, n_ep*per)
        rows = np.take_along_axis(eps, ep_idx, axis=1)        # buffer slots
        feats, actions, rewards = _gather_updates(
            self._feats, self._actions, self._rewards, jnp.asarray(eps),
            jnp.asarray(rows), jnp.asarray(slots))
        return (feats, jnp.asarray(ep_idx), jnp.asarray(slots), actions,
                rewards)
