"""Episode-granular replay buffer Ω.

Tuples (s_t, a_t, r_t, s_{t+1}) of one episode share the same feature
sequence, so the buffer stores per-episode (features, actions, rewards)
and samples minibatches of O tuples as (episode, slot) pairs — the BiLSTM
encodings are then computed once per sampled episode, not per tuple.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


class EpisodeReplay:
    def __init__(self, capacity_episodes: int = 2000, seed: int = 0):
        self.capacity = capacity_episodes
        self.feats: List[np.ndarray] = []
        self.actions: List[np.ndarray] = []
        self.rewards: List[np.ndarray] = []
        self._pos = 0

    def push(self, feats: np.ndarray, actions: np.ndarray,
             rewards: np.ndarray) -> None:
        if len(self.feats) < self.capacity:
            self.feats.append(feats)
            self.actions.append(actions)
            self.rewards.append(rewards)
        else:
            self.feats[self._pos] = feats
            self.actions[self._pos] = actions
            self.rewards[self._pos] = rewards
        self._pos = (self._pos + 1) % self.capacity

    def __len__(self) -> int:
        return sum(len(a) for a in self.actions)

    @property
    def n_episodes(self) -> int:
        return len(self.feats)

    def sample(self, rng: np.random.Generator, n_tuples: int,
               max_episodes: int = 8
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Returns (feats (E,H,F), slots (n,), actions (n,), rewards (n,),
        episode_of_tuple (n,))."""
        n_ep = min(max_episodes, self.n_episodes)
        eps = rng.choice(self.n_episodes, n_ep, replace=False)
        feats = np.stack([self.feats[e] for e in eps])
        H = feats.shape[1]
        per = max(1, n_tuples // n_ep)
        ep_idx, slots = [], []
        for j in range(n_ep):
            s = rng.integers(0, H, per)
            slots.append(s)
            ep_idx.append(np.full(per, j))
        slots = np.concatenate(slots)
        ep_idx = np.concatenate(ep_idx)
        actions = np.stack([self.actions[e] for e in eps])[ep_idx, slots]
        rewards = np.stack([self.rewards[e] for e in eps])[ep_idx, slots]
        return feats, ep_idx, slots, actions, rewards
