"""Episode-granular replay buffer Ω — preallocated array-backed ring.

Tuples (s_t, a_t, r_t, s_{t+1}) of one episode share the same feature
sequence, so the buffer stores per-episode (features, actions, rewards)
and samples minibatches of O tuples as (episode, slot) pairs — the BiLSTM
encodings are then computed once per sampled episode, not per tuple.

Storage is three preallocated numpy arrays (``(capacity, H, F)`` features,
``(capacity, H)`` actions/rewards) allocated on the first push, written as
a ring: ``push_batch`` inserts a whole wave of E episodes in one strided
write (wraparound handled by index arithmetic, not a Python loop), and
``sample``/``sample_updates`` draw minibatches with vectorised
(episode, slot) indexing — no per-episode host loops anywhere, which is
what lets the batched trainer feed its jitted ``lax.scan`` update wave
straight from buffer gathers.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


class EpisodeReplay:
    def __init__(self, capacity_episodes: int = 2000):
        self.capacity = capacity_episodes
        self._feats: np.ndarray | None = None     # (cap, H, F)
        self._actions: np.ndarray | None = None   # (cap, H)
        self._rewards: np.ndarray | None = None   # (cap, H)
        self._n = 0        # episodes currently held (<= capacity)
        self._pos = 0      # next ring write slot

    def _ensure(self, H: int, F: int) -> None:
        if self._feats is None:
            self._feats = np.zeros((self.capacity, H, F), np.float32)
            self._actions = np.zeros((self.capacity, H), np.int64)
            self._rewards = np.zeros((self.capacity, H), np.float32)
        elif self._feats.shape[1:] != (H, F):
            raise ValueError(
                f"episode shape {(H, F)} != buffer {self._feats.shape[1:]}")

    @property
    def H(self) -> int:
        return 0 if self._feats is None else self._feats.shape[1]

    def push(self, feats: np.ndarray, actions: np.ndarray,
             rewards: np.ndarray) -> None:
        """Insert one episode: feats (H, F), actions/rewards (H,)."""
        self.push_batch(np.asarray(feats)[None], np.asarray(actions)[None],
                        np.asarray(rewards)[None])

    def push_batch(self, feats: np.ndarray, actions: np.ndarray,
                   rewards: np.ndarray) -> None:
        """Insert a wave of E episodes in one ring write.

        feats (E, H, F), actions/rewards (E, H). If E exceeds the
        capacity only the most recent ``capacity`` episodes land (ring
        semantics of pushing them one at a time).
        """
        feats = np.asarray(feats, np.float32)
        E, H, F = feats.shape
        self._ensure(H, F)
        if E > self.capacity:       # only the tail survives a full lap
            feats = feats[-self.capacity:]
            actions = np.asarray(actions)[-self.capacity:]
            rewards = np.asarray(rewards)[-self.capacity:]
            self._pos = (self._pos + E) % self.capacity
            E = self.capacity
        slots = (self._pos + np.arange(E)) % self.capacity
        self._feats[slots] = feats
        self._actions[slots] = np.asarray(actions)
        self._rewards[slots] = np.asarray(rewards)
        self._pos = (self._pos + E) % self.capacity
        self._n = min(self._n + E, self.capacity)

    def __len__(self) -> int:
        """Total stored tuples (episodes x slots)."""
        return self._n * self.H

    @property
    def n_episodes(self) -> int:
        return self._n

    def sample(self, rng: np.random.Generator, n_tuples: int,
               max_episodes: int = 8
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                          np.ndarray]:
        """One minibatch of ~n_tuples (episode, slot) pairs.

        Returns ``(feats, ep_idx, slots, actions, rewards)``: feats
        (n_ep, H, F) holds the n_ep <= max_episodes sampled episodes
        once each; ep_idx/slots (n,) index tuples into that stack;
        actions/rewards (n,) are the gathered per-tuple values.
        """
        feats, ep_idx, slots, actions, rewards = self.sample_updates(
            rng, 1, n_tuples, max_episodes=max_episodes)
        return feats[0], ep_idx[0], slots[0], actions[0], rewards[0]

    def sample_updates(self, rng: np.random.Generator, n_updates: int,
                       n_tuples: int, max_episodes: int = 8
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                  np.ndarray, np.ndarray]:
        """U independent minibatches, stacked for a scanned update wave.

        Returns ``(feats, ep_idx, slots, actions, rewards)`` with a
        leading (U,) axis on every array — feats (U, n_ep, H, F), the
        rest (U, n) — ready to be consumed one slice per ``lax.scan``
        step by the batched trainer. All U draws happen in three
        vectorised rng calls (episode choice via argsorted uniforms —
        without-replacement per update — plus one slot and one episode
        index draw), not U x n_ep host calls.
        """
        if self._n == 0:
            raise ValueError("cannot sample from an empty replay buffer")
        U = n_updates
        H = self.H
        n_ep = min(max_episodes, self._n)
        per = max(1, n_tuples // n_ep)
        # (U, n_ep) distinct episode ids per update
        eps = np.argsort(rng.random((U, self._n)), axis=1)[:, :n_ep]
        slots = rng.integers(0, H, (U, n_ep * per))
        ep_idx = np.repeat(np.arange(n_ep)[None], U, axis=0)
        ep_idx = np.repeat(ep_idx, per, axis=1)               # (U, n_ep*per)
        feats = self._feats[eps]                              # (U, n_ep, H, F)
        rows = np.take_along_axis(eps, ep_idx, axis=1)        # buffer slots
        actions = self._actions[rows, slots]
        rewards = self._rewards[rows, slots]
        return feats, ep_idx, slots, actions, rewards
