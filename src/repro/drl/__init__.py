from repro.drl.d3qn import d3qn_init, q_values_all_t  # noqa: F401
from repro.drl.replay import EpisodeReplay  # noqa: F401
from repro.drl.train import D3QNTrainer, make_training_population  # noqa: F401
