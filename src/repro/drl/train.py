"""Algorithm 5 — training the D3QN assignment agent.

Each episode: a fresh random device population (Table I ranges) is
scheduled; HFEL produces the imitation target Ψ̂; the agent assigns the H
devices one per time-slot with ε-greedy exploration; rewards are ±1
(eq. 26); minibatches from the replay buffer train the online network with
the double-DQN target (eq. 22); the target network syncs every J steps.

Two training engines share the episode semantics:

* ``engine="serial"`` — the literature-faithful loop: one population,
  one HFEL target search, one ε-greedy pass and one optimizer step per
  episode. Kept as the parity oracle.
* ``engine="batched"`` (default) — waves of ``wave_size`` episodes. A
  wave samples E populations at once (``sample_population_batch``), runs
  ALL their HFEL target searches in lockstep K-candidate waves
  (``HFELAssigner.assign_batch`` — one allocator dispatch per round for
  the whole wave), acts on every episode in one jitted batched pass
  (``_act_wave``), pushes the wave into the array-backed replay ring in
  one write, and folds the wave's E TD updates into one jitted
  ``lax.scan`` (``_update_wave``) with the target-network sync (every J
  steps) applied inside the scan. Given the same minibatch stream the
  scan reproduces the serial update loop step-for-step (pinned to float
  tolerance in ``tests/test_drl_engine.py``); the main semantic
  difference is that a wave's episodes all sample minibatches from the
  post-wave buffer, where the serial loop interleaves pushes and draws
  (see docs/engine.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.assignment.hfel import HFELAssigner
from repro.drl.d3qn import (d3qn_init, q_values_all_t_jit, q_values_batch,
                            q_values_batch_jit)
from repro.drl.replay import EpisodeReplay
from repro.optim import adam

_SEARCH_SEED_XOR = 0x5EED


def minmax_normalize(feats: np.ndarray) -> np.ndarray:
    """eq. (24): min-max over the H scheduled devices (axis -2, so one
    (H, F) episode and a stacked (E, H, F) wave normalise identically)."""
    lo = feats.min(axis=-2, keepdims=True)
    hi = feats.max(axis=-2, keepdims=True)
    return (feats - lo) / np.maximum(hi - lo, 1e-12)


def drl_features(pop, sched_idx=None) -> np.ndarray:
    """Agent features: gains in dB (raw gains span ~6 orders of magnitude
    and min-max-normalise to a spike at 0), then eq. (24) min-max."""
    feats = np.asarray(pop.features())
    if sched_idx is not None:
        feats = feats[np.asarray(sched_idx)]
    M = pop.n_edges
    feats = feats.copy()
    feats[:, :M] = 10.0 * np.log10(np.maximum(feats[:, :M], 1e-30))
    return minmax_normalize(feats)


def drl_features_batch(popb: cm.PopulationBatch, sched_idx=None
                       ) -> np.ndarray:
    """Vectorised ``drl_features``: (E, H, F) agent features for a whole
    ``PopulationBatch`` in one pass. sched_idx: shared (H,) indices or
    per-population (E, H); None keeps all devices."""
    feats = np.asarray(popb.features())
    if sched_idx is not None:
        sched_idx = np.asarray(sched_idx)
        if sched_idx.ndim == 1:
            feats = feats[:, sched_idx]
        else:
            feats = np.take_along_axis(feats, sched_idx[:, :, None], axis=1)
    M = popb.n_edges
    feats = feats.copy()
    feats[..., :M] = 10.0 * np.log10(np.maximum(feats[..., :M], 1e-30))
    return minmax_normalize(feats)


def _training_sp(sp: cm.SystemParams, H: int) -> cm.SystemParams:
    """Table-I params restricted to a cohort of exactly H devices — the
    single source of the episode-world shape for BOTH engines."""
    return dataclasses.replace(sp, n_devices=H)


def make_training_population(sp: cm.SystemParams, H: int, seed: int
                             ) -> cm.Population:
    """Random population of exactly H scheduled devices (Alg. 5 line 4)."""
    return cm.sample_population(_training_sp(sp, H), seed=seed)


def make_training_population_batch(sp: cm.SystemParams, H: int, seeds
                                   ) -> cm.PopulationBatch:
    """Batched ``make_training_population``: E training worlds stacked,
    world e bitwise-identical to ``make_training_population(sp, H,
    seeds[e])``."""
    return cm.sample_population_batch(_training_sp(sp, H), seeds=seeds)


@functools.partial(jax.jit, static_argnames=("gamma",))
def _td_loss(params, target_params, feats, ep_idx, slots, actions, rewards,
             gamma: float):
    """feats: (E, H, F); tuple indices into episodes."""
    q_on = q_values_batch(params, feats)           # (E, H, M)
    q_tg = q_values_batch(target_params, feats)    # (E, H, M)
    H = feats.shape[1]
    q_sa = q_on[ep_idx, slots, actions]
    nxt = jnp.minimum(slots + 1, H - 1)
    # double DQN: online argmax, target value
    a_star = jnp.argmax(q_on[ep_idx, nxt], axis=-1)
    q_next = q_tg[ep_idx, nxt, a_star]
    terminal = (slots == H - 1)
    y = rewards + gamma * jnp.where(terminal, 0.0, q_next)
    y = jax.lax.stop_gradient(y)
    return jnp.mean(jnp.square(y - q_sa))


@functools.partial(jax.jit, static_argnames=("lr", "gamma"))
def _update_one(params, opt_state, target_params, feats, ep_idx, slots,
                actions, rewards, *, lr: float, gamma: float):
    """One TD minibatch update (serial oracle's optimizer step).

    Module-level with (lr, gamma) static so every trainer instance
    shares one compiled program per shape."""
    opt = adam(lr)
    loss, grads = jax.value_and_grad(_td_loss)(
        params, target_params, feats, ep_idx, slots, actions, rewards,
        gamma)
    params, opt_state = opt.update(grads, opt_state, params)
    return params, opt_state, loss


@functools.partial(jax.jit, static_argnames=("lr", "gamma", "target_sync"))
def _update_wave(params, opt_state, target_params, step0, feats_u,
                 ep_idx_u, slots_u, actions_u, rewards_u, *, lr: float,
                 gamma: float, target_sync: int):
    """U TD updates as one ``lax.scan`` — the serial update loop
    (optimizer step + every-J target sync) folded into a single jitted
    program. Minibatch arrays carry a leading (U,) axis
    (``EpisodeReplay.sample_updates``). Module-level with the
    hyperparameters static, so trainer instances share compilations.
    """
    opt = adam(lr)

    def one(carry, mb):
        params, opt_state, target, step = carry
        feats, ep_idx, slots, acts, rews = mb
        loss, grads = jax.value_and_grad(_td_loss)(
            params, target, feats, ep_idx, slots, acts, rews, gamma)
        params, opt_state = opt.update(grads, opt_state, params)
        step = step + 1
        sync = (step % target_sync == 0)
        target = jax.tree.map(
            lambda t, p: jnp.where(sync, p, t), target, params)
        return (params, opt_state, target, step), loss

    return jax.lax.scan(
        one, (params, opt_state, target_params, step0),
        (feats_u, ep_idx_u, slots_u, actions_u, rewards_u))


@jax.jit
def _act_wave(params, feats, rand_actions, explore):
    """ε-greedy actions for a whole wave in ONE dispatch.

    feats (E, H, F); rand_actions/explore (E, H) host-precomputed
    exploration draws (the rng stays host-side, like the serial path).
    """
    q = q_values_batch(params, feats)
    greedy = jnp.argmax(q, axis=-1)
    return jnp.where(explore, rand_actions, greedy)


@dataclasses.dataclass
class D3QNTrainer:
    sp: cm.SystemParams
    H: int = 50
    hidden: int = 256
    gamma: float = 0.99
    lr: float = 1e-3
    minibatch: int = 128           # O
    target_sync: int = 20          # J
    eps_start: float = 0.9
    eps_end: float = 0.05
    eps_decay_episodes: int = 150
    hfel_transfer: int = 100
    hfel_exchange: int = 300
    alloc_steps: int = 120
    seed: int = 0
    engine: str = "batched"        # "batched" | "serial" (parity oracle)
    wave_size: int = 8             # E: episodes per batched wave

    def __post_init__(self):
        if self.engine not in ("batched", "serial"):
            raise ValueError(
                f"unknown D3QN training engine: {self.engine!r}")
        self.feat_dim = self.sp.n_edges + 3
        key = jax.random.PRNGKey(self.seed)
        self.params = d3qn_init(key, self.feat_dim, self.sp.n_edges,
                                self.hidden)
        self.target_params = jax.tree.map(jnp.copy, self.params)
        self.opt = adam(self.lr)
        self.opt_state = self.opt.init(self.params)
        self.replay = EpisodeReplay()
        self.rng = np.random.default_rng(self.seed)
        self.hfel = HFELAssigner(self.sp, self.hfel_transfer,
                                 self.hfel_exchange, self.alloc_steps)
        self.step = 0
        self.episode = 0
        self.reward_history: List[float] = []

        # bound views of the module-level jitted updates (shared
        # compilation cache across trainer instances)
        self._update = functools.partial(_update_one, lr=self.lr,
                                         gamma=self.gamma)
        self._update_wave = functools.partial(
            _update_wave, lr=self.lr, gamma=self.gamma,
            target_sync=self.target_sync)

    # ------------------------------------------------------------ acting

    def _epsilon_at(self, episode):
        """Vectorised ε schedule — episode may be an int or an array."""
        t = np.minimum(1.0, np.asarray(episode, np.float64)
                       / self.eps_decay_episodes)
        return self.eps_start + (self.eps_end - self.eps_start) * t

    def epsilon(self) -> float:
        return float(self._epsilon_at(self.episode))

    def act_episode(self, feats_norm: np.ndarray, greedy: bool = False
                    ) -> np.ndarray:
        q = np.asarray(q_values_all_t_jit(self.params,
                                          jnp.asarray(feats_norm)))
        actions = q.argmax(axis=-1)
        if not greedy:
            eps = self.epsilon()
            explore = self.rng.random(len(actions)) < eps
            rand = self.rng.integers(0, self.sp.n_edges, len(actions))
            actions = np.where(explore, rand, actions)
        return actions.astype(np.int64)

    def act_batch(self, feats_norm: np.ndarray) -> np.ndarray:
        """Greedy actions for (E, H, F) stacked episodes, one dispatch."""
        q = np.asarray(q_values_batch_jit(self.params,
                                          jnp.asarray(feats_norm)))
        return q.argmax(axis=-1).astype(np.int64)

    # ---------------------------------------------------------- training

    def run_episode(self) -> Tuple[float, float]:
        """One Alg. 5 episode (serial oracle); returns (return, td loss)."""
        pop_seed = int(self.rng.integers(1 << 31))
        pop = make_training_population(self.sp, self.H, seed=pop_seed)
        sched = np.arange(self.H)
        # deterministic search seed per population: HFEL's target pattern
        # is then a (learnable) function of the features, not of rng state
        hfel_assign, _ = self.hfel.assign(
            pop, sched, np.random.default_rng(pop_seed ^ _SEARCH_SEED_XOR))
        feats = drl_features(pop)
        actions = self.act_episode(feats)
        rewards = np.where(actions == hfel_assign, 1.0, -1.0)
        self.replay.push(feats, actions, rewards)

        loss = np.nan
        if len(self.replay) > self.minibatch:
            sample = self.replay.sample(self.rng, self.minibatch)
            feats_b, ep_idx, slots, acts, rews = sample
            self.params, self.opt_state, loss_j = self._update(
                self.params, self.opt_state, self.target_params,
                jnp.asarray(feats_b), jnp.asarray(ep_idx),
                jnp.asarray(slots), jnp.asarray(acts),
                jnp.asarray(rews, jnp.float32))
            loss = float(loss_j)
            self.step += 1
            if self.step % self.target_sync == 0:
                self.target_params = jax.tree.map(jnp.copy, self.params)
        self.episode += 1
        ret = float(rewards.sum())
        self.reward_history.append(ret)
        return ret, loss

    def run_wave(self, n_episodes=None) -> Tuple[np.ndarray, float]:
        """One batched wave of E Alg. 5 episodes.

        Draws E per-episode population seeds from the trainer rng (each
        world bitwise-identical to the serial engine's for the same
        seed; the stream *order* differs, since the serial loop
        interleaves exploration/minibatch draws between seed draws),
        generates ALL the HFEL imitation targets in lockstep
        search waves, acts ε-greedily on the whole wave in one jitted
        pass, pushes the wave into the replay ring in one write, and —
        once the buffer is warm — applies E TD updates as one jitted
        ``lax.scan``. Returns (per-episode returns (E,), losses): the
        losses are the scan's per-update device array (or np.nan before
        the buffer warms) — left unsynced so the update wave overlaps
        the next wave's host work; convert when you read it.
        """
        E = int(self.wave_size if n_episodes is None else n_episodes)
        pop_seeds = [int(self.rng.integers(1 << 31)) for _ in range(E)]
        popb = make_training_population_batch(self.sp, self.H, pop_seeds)
        targets, _ = self.hfel.assign_batch(
            popb, np.arange(self.H),
            [np.random.default_rng(s ^ _SEARCH_SEED_XOR)
             for s in pop_seeds])
        feats = drl_features_batch(popb)
        eps = self._epsilon_at(self.episode + np.arange(E))
        explore = self.rng.random((E, self.H)) < eps[:, None]
        rand = self.rng.integers(0, self.sp.n_edges, (E, self.H))
        actions = np.asarray(_act_wave(
            self.params, jnp.asarray(feats, jnp.float32),
            jnp.asarray(rand), jnp.asarray(explore))).astype(np.int64)
        rewards = np.where(actions == targets, 1.0, -1.0)
        self.replay.push_batch(feats, actions, rewards)
        self.episode += E
        rets = rewards.sum(axis=1)
        self.reward_history.extend(float(r) for r in rets)

        loss = np.nan
        if len(self.replay) > self.minibatch:
            feats_u, ep_idx_u, slots_u, acts_u, rews_u = \
                self.replay.sample_updates(self.rng, E, self.minibatch)
            carry, losses = self._update_wave(
                self.params, self.opt_state, self.target_params,
                jnp.asarray(self.step, jnp.int32),
                jnp.asarray(feats_u), jnp.asarray(ep_idx_u),
                jnp.asarray(slots_u), jnp.asarray(acts_u),
                jnp.asarray(rews_u, jnp.float32))
            self.params, self.opt_state, self.target_params, _ = carry
            # mirror the scan's step counter host-side instead of
            # blocking on the device value: the scan then runs
            # asynchronously under the next wave's host-side sampling
            # and proposal work
            self.step += E
            loss = losses          # device array; sync only when read
        return rets, loss

    def train(self, max_episodes: int, log_every: int = 25,
              verbose: bool = True) -> List[float]:
        def log(loss):
            avg = float(np.mean(self.reward_history[-50:]))
            print(f"  episode {self.episode:4d}  eps={self.epsilon():.2f}"
                  f"  avg50_return={avg:+.1f}  td_loss={loss:.4f}")

        if self.engine == "serial":
            for _ in range(max_episodes):
                _, loss = self.run_episode()
                if verbose and self.episode % log_every == 0:
                    log(loss)
            return self.reward_history

        done = 0
        while done < max_episodes:
            E = min(self.wave_size, max_episodes - done)
            _, losses = self.run_wave(E)
            done += E
            if verbose and (self.episode // log_every) > \
                    ((self.episode - E) // log_every):
                log(float(np.mean(np.asarray(losses))))
        return self.reward_history
