"""Algorithm 5 — training the D3QN assignment agent.

Each episode: a fresh random device population (Table I ranges) is
scheduled; HFEL produces the imitation target Ψ̂; the agent assigns the H
devices one per time-slot with ε-greedy exploration; rewards are ±1
(eq. 26); minibatches from the replay buffer train the online network with
the double-DQN target (eq. 22); the target network syncs every J steps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.assignment.hfel import HFELAssigner
from repro.drl.d3qn import d3qn_init, q_values_all_t, q_values_batch
from repro.drl.replay import EpisodeReplay
from repro.optim import adam


def minmax_normalize(feats: np.ndarray) -> np.ndarray:
    """eq. (24): per-episode min-max over the H scheduled devices."""
    lo = feats.min(axis=0, keepdims=True)
    hi = feats.max(axis=0, keepdims=True)
    return (feats - lo) / np.maximum(hi - lo, 1e-12)


def drl_features(pop, sched_idx=None) -> np.ndarray:
    """Agent features: gains in dB (raw gains span ~6 orders of magnitude
    and min-max-normalise to a spike at 0), then eq. (24) min-max."""
    feats = np.asarray(pop.features())
    if sched_idx is not None:
        feats = feats[np.asarray(sched_idx)]
    M = pop.n_edges
    feats = feats.copy()
    feats[:, :M] = 10.0 * np.log10(np.maximum(feats[:, :M], 1e-30))
    return minmax_normalize(feats)


def make_training_population(sp: cm.SystemParams, H: int, seed: int
                             ) -> cm.Population:
    """Random population of exactly H scheduled devices (Alg. 5 line 4)."""
    sp_h = dataclasses.replace(sp, n_devices=H)
    return cm.sample_population(sp_h, seed=seed)


@functools.partial(jax.jit, static_argnames=("gamma",))
def _td_loss(params, target_params, feats, ep_idx, slots, actions, rewards,
             gamma: float):
    """feats: (E, H, F); tuple indices into episodes."""
    q_on = q_values_batch(params, feats)           # (E, H, M)
    q_tg = q_values_batch(target_params, feats)    # (E, H, M)
    H = feats.shape[1]
    q_sa = q_on[ep_idx, slots, actions]
    nxt = jnp.minimum(slots + 1, H - 1)
    # double DQN: online argmax, target value
    a_star = jnp.argmax(q_on[ep_idx, nxt], axis=-1)
    q_next = q_tg[ep_idx, nxt, a_star]
    terminal = (slots == H - 1)
    y = rewards + gamma * jnp.where(terminal, 0.0, q_next)
    y = jax.lax.stop_gradient(y)
    return jnp.mean(jnp.square(y - q_sa))


@dataclasses.dataclass
class D3QNTrainer:
    sp: cm.SystemParams
    H: int = 50
    hidden: int = 256
    gamma: float = 0.99
    lr: float = 1e-3
    minibatch: int = 128           # O
    target_sync: int = 20          # J
    eps_start: float = 0.9
    eps_end: float = 0.05
    eps_decay_episodes: int = 150
    hfel_transfer: int = 100
    hfel_exchange: int = 300
    alloc_steps: int = 120
    seed: int = 0

    def __post_init__(self):
        self.feat_dim = self.sp.n_edges + 3
        key = jax.random.PRNGKey(self.seed)
        self.params = d3qn_init(key, self.feat_dim, self.sp.n_edges,
                                self.hidden)
        self.target_params = jax.tree.map(jnp.copy, self.params)
        self.opt = adam(self.lr)
        self.opt_state = self.opt.init(self.params)
        self.replay = EpisodeReplay()
        self.rng = np.random.default_rng(self.seed)
        self.hfel = HFELAssigner(self.sp, self.hfel_transfer,
                                 self.hfel_exchange, self.alloc_steps)
        self.step = 0
        self.episode = 0
        self.reward_history: List[float] = []

        @jax.jit
        def _update(params, opt_state, target_params, feats, ep_idx, slots,
                    actions, rewards):
            loss, grads = jax.value_and_grad(_td_loss)(
                params, target_params, feats, ep_idx, slots, actions,
                rewards, self.gamma)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return params, opt_state, loss
        self._update = _update
        self._q_all = jax.jit(q_values_all_t)

    # ------------------------------------------------------------ acting

    def epsilon(self) -> float:
        t = min(1.0, self.episode / self.eps_decay_episodes)
        return self.eps_start + (self.eps_end - self.eps_start) * t

    def act_episode(self, feats_norm: np.ndarray, greedy: bool = False
                    ) -> np.ndarray:
        q = np.asarray(self._q_all(self.params, jnp.asarray(feats_norm)))
        actions = q.argmax(axis=-1)
        if not greedy:
            eps = self.epsilon()
            explore = self.rng.random(len(actions)) < eps
            rand = self.rng.integers(0, self.sp.n_edges, len(actions))
            actions = np.where(explore, rand, actions)
        return actions.astype(np.int64)

    # ---------------------------------------------------------- training

    def run_episode(self) -> Tuple[float, float]:
        """One Alg. 5 episode; returns (undiscounted return, td loss)."""
        pop_seed = int(self.rng.integers(1 << 31))
        pop = make_training_population(self.sp, self.H, seed=pop_seed)
        sched = np.arange(self.H)
        # deterministic search seed per population: HFEL's target pattern
        # is then a (learnable) function of the features, not of rng state
        hfel_assign, _ = self.hfel.assign(
            pop, sched, np.random.default_rng(pop_seed ^ 0x5EED))
        feats = drl_features(pop)
        actions = self.act_episode(feats)
        rewards = np.where(actions == hfel_assign, 1.0, -1.0)
        self.replay.push(feats, actions, rewards)

        loss = np.nan
        if len(self.replay) > self.minibatch:
            sample = self.replay.sample(self.rng, self.minibatch)
            feats_b, ep_idx, slots, acts, rews = sample
            self.params, self.opt_state, loss_j = self._update(
                self.params, self.opt_state, self.target_params,
                jnp.asarray(feats_b), jnp.asarray(ep_idx),
                jnp.asarray(slots), jnp.asarray(acts),
                jnp.asarray(rews, jnp.float32))
            loss = float(loss_j)
            self.step += 1
            if self.step % self.target_sync == 0:
                self.target_params = jax.tree.map(jnp.copy, self.params)
        self.episode += 1
        ret = float(rewards.sum())
        self.reward_history.append(ret)
        return ret, loss

    def train(self, max_episodes: int, log_every: int = 25,
              verbose: bool = True) -> List[float]:
        for _ in range(max_episodes):
            ret, loss = self.run_episode()
            if verbose and self.episode % log_every == 0:
                avg = float(np.mean(self.reward_history[-50:]))
                print(f"  episode {self.episode:4d}  eps={self.epsilon():.2f}"
                      f"  avg50_return={avg:+.1f}  td_loss={loss:.4f}")
        return self.reward_history
