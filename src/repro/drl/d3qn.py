"""Dueling Double Deep Q-Network (D3QN) over the BiLSTM trunk.

Q(s, a; θ) = V(s; φ, ρ) + A(s, a; φ, ζ) − mean_a' A(s, a'; φ, ζ)   (eq. 20)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.drl.bilstm import bilstm_encode, bilstm_init
from repro.models.layers import dense_init


def d3qn_init(key, feat_dim: int, n_actions: int, hidden: int = 256):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc = 2 * hidden
    return {
        "bilstm": bilstm_init(k1, feat_dim, hidden),
        "trunk": {"w": dense_init(k2, enc, hidden), "b": jnp.zeros((hidden,))},
        "v_head": {"w": dense_init(k3, hidden, 1), "b": jnp.zeros((1,))},
        "a_head": {"w": dense_init(k4, hidden, n_actions),
                   "b": jnp.zeros((n_actions,))},
    }


def q_values_all_t(params, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (H, F) episode features -> Q (H, n_actions) for every slot."""
    enc = bilstm_encode(params["bilstm"], feats)             # (H, 2h)
    z = jax.nn.relu(enc @ params["trunk"]["w"] + params["trunk"]["b"])
    v = z @ params["v_head"]["w"] + params["v_head"]["b"]    # (H, 1)
    a = z @ params["a_head"]["w"] + params["a_head"]["b"]    # (H, M)
    return v + a - jnp.mean(a, axis=-1, keepdims=True)


q_values_batch = jax.vmap(q_values_all_t, in_axes=(None, 0))

# Module-level jitted entry points, shared by every consumer (the trainer's
# greedy/parity paths and the deployment ``DRLAssigner``) so the compiled
# programs are cached once per shape instead of once per instance.
q_values_all_t_jit = jax.jit(q_values_all_t)
q_values_batch_jit = jax.jit(q_values_batch)
