"""Bidirectional LSTM trunk for the D3QN agent (paper Fig. 2).

The agent's state at slot t (eq. 25) is (forward input χ_{n_1..n_t},
backward input χ_{n_t..n_H}). Because the device feature sequence is
fixed for the whole episode, one forward scan + one backward scan yield
the encodings of ALL H states at once:

    enc(s_t) = [h_fwd[t] ; h_bwd[t]]

h_fwd[t] = forward LSTM state after consuming χ_t; h_bwd[t] = backward
LSTM state after consuming χ_H..χ_t. This makes both acting and replay
training O(H) instead of O(H^2) LSTM steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def lstm_init(key, in_dim: int, hidden: int):
    k1, k2 = jax.random.split(key)
    return {
        "wx": dense_init(k1, in_dim, 4 * hidden),
        "wh": dense_init(k2, hidden, 4 * hidden) * 0.3,
        "b": jnp.zeros((4 * hidden,)),
    }


def lstm_scan(params, xs: jnp.ndarray) -> jnp.ndarray:
    """xs: (T, in_dim) -> hidden states (T, hidden).

    The input projection is hoisted out of the scan — one (T, in) @
    (in, 4h) matmul up front instead of T tiny ones inside the loop —
    so each scan step only pays the recurrent h @ wh matmul. Under the
    replay-training vmap this turns the per-step input work into a
    single batched GEMM.
    """
    hidden = params["wh"].shape[0]
    zx = xs @ params["wx"] + params["b"]          # (T, 4h), scan-invariant

    def cell(carry, zx_t):
        h, c = carry
        z = zx_t + h @ params["wh"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((hidden,))
    (_, _), hs = jax.lax.scan(cell, (h0, h0), zx)
    return hs


def bilstm_init(key, in_dim: int, hidden: int):
    kf, kb = jax.random.split(key)
    return {"fwd": lstm_init(kf, in_dim, hidden),
            "bwd": lstm_init(kb, in_dim, hidden)}


def bilstm_encode(params, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (H, F) -> per-slot state encodings (H, 2*hidden)."""
    h_f = lstm_scan(params["fwd"], feats)                    # h_f[t] after χ_t
    h_b = lstm_scan(params["bwd"], feats[::-1])[::-1]        # h_b[t] from χ_H..χ_t
    return jnp.concatenate([h_f, h_b], axis=-1)
