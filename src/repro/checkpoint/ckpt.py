"""Tiny pytree checkpointing: npz payload + JSON treedef manifest.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
Restores to host numpy; caller device-puts/shards as needed.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(tree: Pytree, directory: str, step: int) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(arrays),
                   "treedef": str(treedef)}, f)
    return d


def restore_pytree(template: Pytree, directory: str,
                   step: Optional[int] = None) -> Pytree:
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints under {directory}"
    d = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat]
    leaves = [data[k] for k in keys]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for n in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", n))]
    return max(steps) if steps else None
