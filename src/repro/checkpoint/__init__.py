from repro.checkpoint.ckpt import save_pytree, restore_pytree, latest_step  # noqa: F401
