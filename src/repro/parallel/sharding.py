"""Parameter/activation sharding rules: FSDP(data) x TP(model) [+ pod].

Mesh axes:
  pod   — cloud tier: one pod per HFL "edge-server group" (multi-pod only)
  data  — devices-within-edge cohort: batch/FSDP axis
  model — tensor/expert parallel axis

Param rules (leaf-name based, applied to the stacked block trees whose
leading axis is the layer-stack):

  tp_strategy="heads" (Megatron col/row over attention heads):
    wq (D, Hq*hd)        -> (data, model)     col-parallel
    wk/wv (D, Hkv*hd)    -> (data, None)      kv computed replicated (GQA
                                              kv-heads < 16; tiny matmul)
    wo (Hq*hd, D)        -> (model, data)     row-parallel
  tp_strategy="feature" (n_heads % 16 != 0 — musicgen, llama4-scout):
    attention weights FSDP-only; MLP/experts still TP-sharded.

  mlp w_gate/w_up (D,F)  -> (data, model);  w_down (F,D) -> (model, data)
  moe experts (E,D,F)    -> (model, data, None)   expert parallelism
  embed (V, D)           -> (model, data);  lm_head (D,V) -> (data, model)
  mamba in_proj (D, dip) -> (data, model);  out_proj (di,D) -> (model, data)
  norms / scalars        -> replicated

Every rule is divisibility-checked against the actual leaf shape and the
mesh axis sizes; axes that do not divide are dropped (e.g. batch=1 for
long_500k decode).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def fit_spec(mesh: Mesh, shape, spec: P) -> P:
    """Drop spec axes whose size does not divide the dimension."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, name in zip(shape, entries):
        if name is not None and dim % _axis_size(mesh, name) == 0:
            out.append(name)
        else:
            out.append(None)
    return P(*out)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ------------------------------------------------------- sweep lane axis

def lane_spec() -> P:
    """Partition spec for lane-stacked sweep arrays: shard the leading
    (seed-lane) axis, replicate the rest (specs shorter than the rank
    leave trailing dims unsharded)."""
    return P("lane")


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding placing the leading lane axis of a (S, ...) array —
    or every leaf of a lane-stacked pytree via ``jax.device_put`` — over
    a 1-D ``sweep_mesh``. S must be a multiple of the lane axis size
    (``SweepRunner`` pads with dead lanes, see ``pad_lanes``)."""
    return NamedSharding(mesh, lane_spec())


def pad_lanes(n_lanes: int, n_devices: int) -> int:
    """Smallest multiple of n_devices >= n_lanes (lane-block padding)."""
    return -(-n_lanes // n_devices) * n_devices


def round_lane_spec() -> P:
    """Partition spec for round-major lane-stacked arrays — the fused
    sweep scan's (R, S, ...) schedule tensor inputs and its (R, S)
    per-round cost/accuracy outputs: the scan (round) axis is carried
    in-program on every device, only the lane axis shards."""
    return P(None, "lane")


# ------------------------------------------------------------ parameters

def _param_rule(path: str, ndim: int, cfg: ModelConfig) -> P:
    heads_tp = cfg.tp_strategy == "heads"

    def blocked(*spec):
        """Prepend None for the layer-stack axis if the leaf is stacked."""
        if ndim == len(spec) + 1:
            return P(None, *spec)
        return P(*spec)

    if path.endswith("embed"):
        return P("model", "data")
    if path.endswith("lm_head"):
        return P("data", "model")
    if "scale" in path or path.endswith(("A_log", "D_skip", "dt_bias", "b")):
        return P()
    if "mix/" in path or "/mix" in path:
        if path.endswith("wq"):
            return blocked("data", "model") if heads_tp else blocked("data", None)
        if path.endswith(("wk", "wv")):
            return blocked("data", None)
        if path.endswith("wo"):
            return blocked("model", "data") if heads_tp else blocked(None, "data")
        if path.endswith(("in_proj", "wz", "wx")):
            return blocked("data", "model")
        if path.endswith(("wb", "wc", "wdt")):
            return blocked("data", None)
        if path.endswith("out_proj"):
            return blocked("model", "data")
        if path.endswith(("conv_w", "conv_x")):
            return blocked("model", None)
        if path.endswith(("conv_b", "conv_c")):
            return blocked()
    if path.endswith(("w_gate", "w_up")):
        # (D,F) | (layers,D,F) dense -> col-parallel; (layers,E,D,F) or
        # (E,D,F) experts -> expert-parallel over model, FSDP on D
        if ndim == 4:
            return P(None, "model", "data", None)
        if ndim == 3 and "blocks" not in path:
            return P("model", "data", None)
        return blocked("data", "model")
    if path.endswith("w_down"):
        if ndim == 4:
            return P(None, "model", None, "data")
        if ndim == 3 and "blocks" not in path:
            return P("model", None, "data")
        return blocked("model", "data")
    if path.endswith("router"):
        return blocked("data", None)
    return P()


def _leaf_path(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh):
    """Pytree of PartitionSpec matching `params`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        p = _leaf_path(path)
        spec = _param_rule(p, leaf.ndim, cfg)
        specs.append(fit_spec(mesh, leaf.shape, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, cfg, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, cfg, mesh))


# ------------------------------------------------------------ activations

def act_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    dp = batch_axes(mesh)
    heads_tp = cfg.tp_strategy == "heads"
    # sequence parallelism: the residual stream (the remat-scan carry that
    # dominates activation memory) is additionally sharded over `model`
    resid = P(dp, "model", None) if cfg.seq_shard else P(dp, None, None)
    return {
        "act_resid": resid,
        "act_resid_decode": P(dp, None, None),
        "act_heads": P(dp, None, "model", None) if heads_tp
                     else P(dp, None, None, None),
        "act_kv_heads": P(dp, None, None, None),
        # chunked-prefill scores (B, Hkv, G, bq, S_kv)
        "attn_scores_heads": P(dp, "model", None, None, None),
        "attn_scores_seq": P(dp, None, None, None, "model"),
        "ssm_heads": P(dp, None, "model", None),
        "ssm_chunk_x": P(dp, None, None, "model", None),
        "ssm_chunk_bc": P(dp, None, None, "model", None),
        "ssm_chunk_cum": P(dp, None, None, "model"),
        "ssm_chunk_ij": P(dp, None, None, None, "model"),
        # (gd, E, C, D/F): data-chunks over batch axes, experts over model
        "moe_buffer": P(dp, "model", None, None),
        "moe_hidden": P(dp, "model", None, None),
        "logits": P(dp, None, "model"),
    }


# ------------------------------------------------------------- caches

def cache_specs(cache, cfg: ModelConfig, mesh: Mesh):
    """Decode-cache shardings: batch over (pod,data) when divisible;
    KV slots over model (sequence-parallel cache); SSM heads over model."""
    dp = batch_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, x in flat:
        name = _leaf_path(path)
        if name.endswith(("k", "v")):        # (nb, B, slots, Hkv, hd)
            spec = P(None, dp, "model", None, None)
        elif name.endswith("ssm"):           # (nb, B, H, hd, dstate)
            spec = P(None, dp, "model", None, None)
        elif name.endswith("conv"):          # (nb, B, W-1, conv_dim)
            spec = P(None, dp, None, "model")
        else:
            spec = P()
        specs.append(fit_spec(mesh, x.shape, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_shardings(cache, cfg, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cache, cfg, mesh))
