"""Activation-sharding hooks threaded through the model code.

Models call ``sharder.act(x, kind)`` at layer boundaries; the default
``NoopSharder`` makes single-device runs (tests, CPU training) free of any
mesh dependence, while ``MeshSharder`` applies
``jax.lax.with_sharding_constraint`` according to the logical-axis rules in
``repro.parallel.sharding``.
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding


class Sharder:
    #: number of batch shards (drives per-shard MoE dispatch chunking)
    data_chunks: int = 1

    def act(self, x, kind: str):
        raise NotImplementedError


class NoopSharder(Sharder):
    def act(self, x, kind: str):
        return x


class MeshSharder(Sharder):
    """kind -> PartitionSpec table, applied inside jit with a mesh."""

    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = rules
        self.data_chunks = int(mesh.shape.get("data", 1)) * \
            int(mesh.shape.get("pod", 1))

    def act(self, x, kind: str):
        spec = self.rules.get(kind)
        if spec is None or x.ndim != len(spec):
            return x
        from repro.parallel.sharding import fit_spec
        spec = fit_spec(self.mesh, x.shape, spec)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


NOOP = NoopSharder()
