from repro.parallel.sharder import Sharder, NoopSharder, MeshSharder  # noqa: F401
