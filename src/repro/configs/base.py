"""Architecture + input-shape configuration dataclasses.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exposing ``CONFIG`` (the exact assigned full-size config, with the source
citation) and ``smoke_config()`` (a reduced same-family variant for CPU
tests: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # every `every` layers one MoE MLP (1 = all layers MoE)
    every: int = 1
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: period-P blocks, attn at index attn_pos within the block
    hybrid_period: int = 0
    hybrid_attn_pos: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # "heads": Megatron col/row TP over attention heads (requires n_heads%tp==0)
    # "feature": row-parallel over d_model features (any head count)
    tp_strategy: str = "heads"
    sliding_window: int = 0          # 0 = full attention; >0 = SWA window
    # modality frontend stub: number of prefix embedding positions supplied
    # directly as dense vectors by input_specs() (vlm patches / audio frames)
    n_prefix_embeds: int = 0
    n_codebooks: int = 1             # audio: parallel codebooks
    dtype: str = "bfloat16"
    remat: bool = True
    microbatches: int = 1            # grad-accumulation splits per train step
    unroll_layers: bool = False      # unroll layer/microbatch scans (FLOPs
                                     # probes: XLA cost analysis counts a
                                     # while-loop body once)
    seq_shard: bool = False          # Megatron-style sequence parallelism:
                                     # residual stream sharded over `model`
    unpadded_vocab: int = 0          # true vocab before TP padding (0 = exact)
    citation: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, idx: int) -> str:
        """'attn' or 'ssm' for the token-mixing sublayer of layer idx."""
        if self.family == "ssm":
            return "ssm"
        if self.hybrid_period > 0:
            return "attn" if idx % self.hybrid_period == self.hybrid_attn_pos else "ssm"
        return "attn"

    def mlp_kind(self, idx: int) -> str:
        """'moe' | 'dense' | 'none' for the channel-mixing sublayer."""
        if self.d_ff == 0:
            return "none"          # pure SSM blocks (mamba2): no MLP sublayer
        if self.is_moe and idx % self.moe.every == self.moe.every - 1:
            return "moe"
        return "dense"

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        total = V * D                                     # embed
        if not self.tie_embeddings:
            total += D * V                                # lm head
        total += D                                        # final norm
        if self.family == "audio" and self.n_codebooks > 1:
            total += (self.n_codebooks - 1) * V * D       # extra codebook embeds
            total += (self.n_codebooks - 1) * D * V       # extra heads
        ssm = self.ssm or SSMConfig()
        di = ssm.d_inner(D)
        nh = ssm.n_heads(D)
        for i in range(self.n_layers):
            total += 2 * D                                # two norms
            if self.layer_kind(i) == "attn":
                total += D * Hq * hd + 2 * D * Hkv * hd + Hq * hd * D
            else:
                # in_proj -> [z, x, B, C, dt], conv, A, D, norm, out_proj
                conv_dim = di + 2 * ssm.n_groups * ssm.d_state
                total += D * (2 * di + 2 * ssm.n_groups * ssm.d_state + nh)
                total += conv_dim * ssm.conv_width + 2 * nh + di  # conv + A/D + gate-norm
                total += di * D
            if self.mlp_kind(i) == "moe":
                m = self.moe
                total += D * m.num_experts                # router
                total += m.num_experts * 3 * D * F
            else:
                total += 3 * D * F
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.is_moe:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.mlp_kind(i) == "moe")
        unused = n_moe_layers * (m.num_experts - m.top_k) * 3 * self.d_model * self.d_ff
        return full - unused


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
