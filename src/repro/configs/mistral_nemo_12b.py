"""mistral-nemo-12b — dense decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407] 40 layers, d_model 5120, 32 q heads
with explicit head_dim 128 (q proj 5120->4096), GQA kv=8, d_ff 14336,
vocab 131072. rope_theta 1e6 for long context.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    microbatches=16,
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemo-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=48, d_ff=256, vocab_size=277,
        rope_theta=1e6, dtype="float32", citation=CONFIG.citation)
