"""mamba2-2.7b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] 64 layers, d_model 2560, d_inner 5120 (expand 2),
80 SSD heads of head_dim 64, d_state 128, vocab 50280 (padded to
50304 = 393*128 for 16-way TP). No attention; d_ff=0 (the Mamba block is
the whole layer — our layer wrapper still applies a dense MLP when
d_ff>0, so d_ff=0 disables it via mlp identity).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50304,
    unpadded_vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    microbatches=8,
    citation="arXiv:2405.21060 (Mamba-2 / SSD)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=128,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=257,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk=16),
        dtype="float32", citation=CONFIG.citation)
