"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] 48 layers, d_model 1536, 24 heads MHA (kv=24),
d_ff 6144, vocab 2048 per codebook, 4 parallel codebooks (delay pattern
handled at the data layer; the model embeds the 4 streams additively and
predicts 4 heads — MusicGen's parallel-with-delay interleave).

The EnCodec codec itself is a STUB (carve-out): ``input_specs`` provides
the (B, S, 4) token streams.

24 heads are not divisible by the 16-way model axis -> feature-dim
(row-parallel) tensor parallelism instead of head sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    tp_strategy="feature",
    microbatches=8,
    citation="arXiv:2306.05284 (MusicGen medium)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio", n_layers=2, d_model=96,
        n_heads=6, n_kv_heads=6, d_ff=192, vocab_size=67, n_codebooks=4,
        tp_strategy="feature", dtype="float32", citation=CONFIG.citation)
