"""The paper's own HFL task model (Section VI): 2-conv CNN.

Not a transformer — selected via the HFL framework (``repro.core``), not
the big-model launcher. Kept in the registry for completeness so
``--arch hfl-cnn`` resolves in the examples.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class HFLCNNConfig:
    name: str = "hfl-cnn"
    family: str = "cnn"
    conv_channels: tuple = (15, 28)
    kernel: int = 5
    datasets: tuple = ("fmnist_syn", "cifar_syn")
    citation: str = "paper §VI (two 5x5 convs + two linear layers)"


CONFIG = HFLCNNConfig()


def smoke_config():
    return CONFIG
