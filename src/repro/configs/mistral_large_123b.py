"""mistral-large-123b — dense decoder.

[hf:mistralai/Mistral-Large-Instruct-2407] 88 layers, d_model 12288,
96 q heads (GQA kv=8, head_dim 128), d_ff 28672, vocab 32768.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    microbatches=16,
    seq_shard=True,
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke", family="dense", n_layers=2, d_model=192,
        n_heads=6, n_kv_heads=2, head_dim=32, d_ff=384, vocab_size=263,
        dtype="float32", citation=CONFIG.citation)
