"""llama4-scout-17b-a16e — MoE decoder (16 experts, top-1), early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48 layers, d_model 5120, 40 q heads
(GQA kv=8), expert d_ff 8192, vocab 202048 (padded to 202752 = 99*2048),
MoE 16 experts top-1 every layer. Early-fusion multimodality is out of
scope of the assigned backbone (text path only). 40 heads are not
divisible by 16-way TP -> feature-dim tensor parallelism.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202752,
    unpadded_vocab=202048,
    moe=MoEConfig(num_experts=16, top_k=1, every=1, capacity_factor=1.25),
    tp_strategy="feature",
    microbatches=16,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="scout-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=241,
        moe=MoEConfig(num_experts=4, top_k=1, every=1),
        tp_strategy="feature", dtype="float32", citation=CONFIG.citation)
