"""internvl2-26b — VLM: InternViT (stub) + InternLM2-20B language decoder.

[arXiv:2404.16821] 48 layers, d_model 6144, 48 q heads (GQA kv=8),
d_ff 16384, vocab 92553 (padded to 92672 = 724*128 for 16-way TP).
Vision frontend is a STUB: ``input_specs`` provides 256 patch embeddings
(one tile) of width d_model via the projector interface.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92672,
    unpadded_vocab=92553,
    n_prefix_embeds=256,
    microbatches=16,
    citation="arXiv:2404.16821 (InternVL2; InternLM2-20B backbone)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=499,
        n_prefix_embeds=16, dtype="float32", citation=CONFIG.citation)
