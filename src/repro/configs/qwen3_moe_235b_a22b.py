"""qwen3-moe-235b-a22b — fine-grained MoE (128 experts, top-8).

[hf:Qwen/Qwen3-30B-A3B family scaled per assignment] 94 layers, d_model
4096, 64 q heads (GQA kv=4, head_dim 128), expert d_ff 1536, vocab
151936 (=1187*128), MoE 128 experts top-8 every layer.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    moe=MoEConfig(num_experts=128, top_k=8, every=1, capacity_factor=1.25),
    microbatches=16,
    citation="hf:Qwen/Qwen3-235B-A22B",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=269,
        moe=MoEConfig(num_experts=4, top_k=2, every=1),
        dtype="float32", citation=CONFIG.citation)
