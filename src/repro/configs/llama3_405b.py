"""llama3-405b — dense decoder, the largest assigned config.

[arXiv:2407.21783] 126 layers, d_model 16384, 128 q heads (GQA kv=8,
head_dim 128), d_ff 53248, vocab 128256 (=1002*128), rope_theta 5e5.
long_500k decode runs with a sliding-window KV-cache variant (window
8192) — full-attention 500k cache is deliberately out of scope (DESIGN.md
§Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    microbatches=8,
    seq_shard=True,
    citation="arXiv:2407.21783 (Llama 3 405B)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=509,
        rope_theta=5e5, dtype="float32", citation=CONFIG.citation)
