"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import List, Tuple

from repro.configs.base import InputShape, ModelConfig

_MODULES = {
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "llama3-405b": "repro.configs.llama3_405b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "hfl-cnn": "repro.configs.hfl_cnn",
}

ARCH_IDS: List[str] = [a for a in _MODULES if a != "hfl-cnn"]

# HFL payload archs exercised by tests/bench_model_zoo: the paper CNN
# plus one arch per decoder family (dense / ssm / moe). Any _MODULES id
# resolves through get_hfl_spec; these are the CI smoke set.
HFL_SMOKE_ARCHS: Tuple[str, ...] = (
    "hfl-cnn", "mistral-nemo-12b", "mamba2-2.7b", "qwen3-moe-235b-a22b")


@functools.lru_cache(maxsize=None)
def get_hfl_spec(arch: str):
    """Resolve ``--arch`` to the :class:`repro.models.spec.ModelSpec`
    the HFL engines train over.

    ``hfl-cnn`` is the paper's FashionMNIST/CIFAR CNN (the default —
    bitwise-identical to the pre-spec engines). Every other registry id
    maps to its CPU-trainable ``smoke_config()`` variant (remat off,
    f32) wrapped as a sequence classifier over the synthetic
    ``make_seq_dataset`` task; the cost model prices whatever payload
    comes back via ``model_bits``. Cached so repeated resolution returns
    the SAME spec object — ``apply_fn`` is a static jit argument and
    must not fragment the engines' jit caches.
    """
    from repro.models import spec as spec_lib
    if arch == "hfl-cnn":
        return spec_lib.cnn_spec()
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    cfg = dataclasses.replace(get_smoke_config(arch),
                              remat=False, dtype="float32")
    return spec_lib.seq_spec(arch, cfg)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.smoke_config()


def variant_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-conditioned config variant.

    long_500k decode requires sub-quadratic state: SSM/hybrid keep their
    constant-size state; any config with attention layers switches to a
    sliding-window KV cache (window 8192) — Jamba's own long-context
    choice, applied to the dense/vlm/moe/audio archs as the documented
    SWA variant (DESIGN.md §Arch-applicability).
    """
    if shape.name == "long_500k" and cfg.family != "ssm":
        return dataclasses.replace(cfg, sliding_window=8192)
    return cfg


def decode_supported(cfg: ModelConfig) -> bool:
    """All assigned archs are decoders; encoder-only archs would return
    False here and skip decode shapes."""
    return True
