"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE.

[arXiv:2403.19887 / 2408.12570] 72 layers, d_model 8192, 64 q heads (GQA
kv=8), d_ff 24576, vocab 65536, MoE 16 experts top-2 every other layer;
attention appears once per 8-layer block (Jamba's 1:7 attn:mamba ratio).
Mamba-2-style SSM sublayers (d_state 128, head_dim 64, expand 2).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, every=2, capacity_factor=1.25),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    hybrid_period=8,
    hybrid_attn_pos=4,
    sliding_window=0,
    microbatches=16,
    citation="arXiv:2403.19887 (Jamba-1.5)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=503,
        moe=MoEConfig(num_experts=4, top_k=2, every=2),
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk=16),
        hybrid_period=2, hybrid_attn_pos=0, dtype="float32",
        citation=CONFIG.citation)
