"""chatglm3-6b — dense decoder with 2-group GQA (MQA-ish) and 2d RoPE.

[arXiv:2406.12793 (GLM-4 report, ChatGLM family)] 28 layers, d_model
4096, 32 q heads, GQA kv=2, d_ff 13696, vocab 65024. ChatGLM applies
rotary embeddings to half the head dims (2d RoPE); we implement standard
full-dim RoPE and note the deviation (frequency layout does not change
any system-level property measured here).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    microbatches=8,
    citation="arXiv:2406.12793 (ChatGLM)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=288, vocab_size=251,
        dtype="float32", citation=CONFIG.citation)
