"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import).

Mesh semantics (HFL mapping, DESIGN.md §3):
  pod   (2)  — cloud tier: each pod is one edge-server cohort
  data  (16) — devices within an edge cohort (batch / FSDP axis)
  model (16) — tensor/expert parallel within a cohort
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """1-device mesh with the same axis names (for CPU tests)."""
    shape = (1, 1, 1) if multi_pod else (1, 1)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def sweep_mesh(n_devices: int | None = None):
    """1-D ``Mesh(("lane",))`` over the local devices for lane-parallel
    sweeps (``SweepRunner(shard=True)``): seed lanes are embarrassingly
    parallel, so the sweep layer only ever shards the stacked lane axis.

    n_devices: use the first n local devices (default: all of them). On
    CPU the device count comes from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set BEFORE
    jax import — which is why this is a function, not a module constant
    (same rule as the production meshes above).
    """
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"sweep_mesh: asked for {n_devices} devices, only "
                f"{len(devs)} visible")
        devs = devs[:n_devices]
    return jax.make_mesh((len(devs),), ("lane",), devices=devs)


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link direction
