"""Long-running streaming HFL service — the async engine under traffic.

    PYTHONPATH=src python -m repro.launch.serve --smoke
    PYTHONPATH=src python -m repro.launch.serve --rounds 50 \
        --traffic diurnal --buffer-size 4 \
        --ckpt-dir /tmp/hfl_ckpt --ckpt-every 10

Drives :class:`repro.core.async_engine.AsyncHFLEngine` round by round on
a virtual clock: every round streams one JSON line to stdout (round id,
virtual time, accuracy, staleness/waste accounting), the model is
evaluated every ``--eval-every`` rounds and checkpointed every
``--ckpt-every`` rounds via ``repro.checkpoint.ckpt``
(``<dir>/step_<round>/``). Traffic presets:

* ``always-on``  — the degenerate sync-parity fleet (no churn),
* ``stationary`` — alternating-renewal dropouts + 20% 4x stragglers,
* ``diurnal``    — non-homogeneous Poisson joins, sinusoidal load,
* ``bursty``     — diurnal plus periodic burst windows.

The old LM decode serving CLI lives on as ``repro.launch.serve_lm``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Dict, Optional

from repro.checkpoint import ckpt
from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.core.async_engine import AsyncConfig, AsyncHFLEngine
from repro.core.traffic import TrafficGenerator, TrafficParams
from repro.data import make_dataset, partition_noniid


def build_world(n_devices: int, n_edges: int, n_train: int, n_test: int,
                seed: int, L: Optional[int] = None,
                Q: Optional[int] = None):
    """Population + synthetic non-IID federated dataset (quickstart
    recipe) sized for a streaming run."""
    sp = cm.SystemParams(n_devices=n_devices, n_edges=n_edges,
                         d_range=(50, 90))
    if L is not None:
        sp = dataclasses.replace(sp, L=L)
    if Q is not None:
        sp = dataclasses.replace(sp, Q=Q)
    pop = cm.sample_population(sp, seed=seed)
    X, y, Xt, yt = make_dataset("fmnist_syn", n_train=n_train,
                                n_test=n_test, seed=seed)
    fed = partition_noniid(X, y, Xt, yt, n_devices=n_devices,
                           size_range=(20, 40), seed=seed)
    return sp, pop, fed


def build_trace(traffic: str, n_devices: int, seed: int,
                horizon_s: float = 2e4) -> cm.AvailabilityTrace:
    """Availability trace for a named traffic preset."""
    if traffic == "always-on":
        return cm.AvailabilityTrace.always_on(n_devices)
    if traffic == "stationary":
        ap = cm.AvailabilityParams(p_offline0=0.1, mean_up_s=900.0,
                                   mean_down_s=120.0, straggler_frac=0.2,
                                   straggler_scale=4.0)
        return cm.sample_availability(ap, n_devices, seed=seed)
    if traffic in ("diurnal", "bursty"):
        tp = TrafficParams(
            join_rate=n_devices / 600.0, mean_session_s=600.0,
            diurnal_amp=0.8, diurnal_period_s=3600.0, p_online0=0.5,
            burst_mult=5.0 if traffic == "bursty" else 1.0,
            burst_every_s=3600.0 if traffic == "bursty" else float("inf"),
            burst_len_s=300.0 if traffic == "bursty" else 0.0)
        return TrafficGenerator(tp, n_devices, seed=seed).make_trace(
            horizon_s)
    raise ValueError(f"unknown traffic preset {traffic!r}")


def run_serve(n_devices: int = 40, n_edges: int = 5, H: int = 20,
              rounds: int = 10, scheduler: str = "fedavg",
              traffic: str = "always-on",
              buffer_size: Optional[int] = None,
              staleness_exp: float = 0.5, eval_every: int = 1,
              ckpt_every: int = 0, ckpt_dir: Optional[str] = None,
              out_json: Optional[str] = None, seed: int = 0,
              n_train: int = 2000, n_test: int = 500,
              alloc_steps: int = 100, L: Optional[int] = None,
              Q: Optional[int] = None, codec: str = "none",
              topk_frac: float = 0.05, log=print) -> Dict:
    """Stream ``rounds`` async HFL rounds; returns the engine summary.

    Importable/testable core of the CLI: ``log`` receives one JSON line
    per round — with an uplink ``codec`` it carries the compressed
    ``msg_bits``/``uplink_bytes``/``codec`` accounting (checkpoint/eval
    cadence is asserted by ``tests/test_launch_cli.py`` through this
    entry point).
    """
    sp, pop, fed = build_world(n_devices, n_edges, n_train, n_test, seed,
                               L=L, Q=Q)
    trace = build_trace(traffic, n_devices, seed)
    cfg = AsyncConfig(H=H, scheduler=scheduler, buffer_size=buffer_size,
                      staleness_exp=staleness_exp, seed=seed,
                      alloc_steps=alloc_steps,
                      compression=comp.CompressionConfig(
                          codec=codec, topk_frac=topk_frac, seed=seed))
    engine = AsyncHFLEngine(sp, pop, fed, cfg, trace=trace)

    n_ckpts = 0
    for r in range(1, rounds + 1):
        rec = engine.step_round(
            collect_eval=eval_every > 0 and r % eval_every == 0)
        log(json.dumps(rec, default=float))
        if ckpt_every > 0 and ckpt_dir and r % ckpt_every == 0:
            ckpt.save_pytree(engine.model_params, ckpt_dir, r)
            n_ckpts += 1

    summary = engine.summary()
    summary["n_checkpoints"] = n_ckpts
    summary["traffic"] = traffic
    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as fh:
            json.dump(summary, fh, indent=1, default=float)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny world / 3 rounds (CI smoke)")
    ap.add_argument("--devices", type=int, default=40)
    ap.add_argument("--edges", type=int, default=5)
    ap.add_argument("--H", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--scheduler", default="fedavg",
                    choices=("fedavg", "ikc", "vkc"))
    ap.add_argument("--traffic", default="stationary",
                    choices=("always-on", "stationary", "diurnal",
                             "bursty"))
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="edge flush threshold (default: wait-for-all)")
    ap.add_argument("--staleness-exp", type=float, default=0.5)
    ap.add_argument("--eval-every", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="summary JSON path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codec", default="none", choices=comp.CODECS,
                    help="uplink update codec (error-feedback residuals)")
    ap.add_argument("--topk-frac", type=float, default=0.05,
                    help="kept fraction per tensor for --codec topk")
    args = ap.parse_args()

    kw = dict(n_devices=args.devices, n_edges=args.edges, H=args.H,
              rounds=args.rounds, scheduler=args.scheduler,
              traffic=args.traffic, buffer_size=args.buffer_size,
              staleness_exp=args.staleness_exp,
              eval_every=args.eval_every, ckpt_every=args.ckpt_every,
              ckpt_dir=args.ckpt_dir, out_json=args.out, seed=args.seed,
              codec=args.codec, topk_frac=args.topk_frac)
    if args.smoke:
        kw.update(n_devices=10, n_edges=3, H=6, rounds=3, n_train=300,
                  n_test=120, alloc_steps=40, L=2, Q=3)
    summary = run_serve(**kw)
    acc = summary["final_acc"]
    print(f"served {summary['rounds']} rounds to t={summary['t_virtual']:.1f}s "
          f"virtual: acc={'-' if acc is None else f'{acc:.3f}'} "
          f"updates={summary['n_updates']} stale={summary['n_stale']} "
          f"wasted={summary['wasted_j']:.1f}J "
          f"ckpts={summary['n_checkpoints']}")


if __name__ == "__main__":
    main()
