"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Proves the distribution config is coherent without hardware: 512 host
placeholder devices let jax.make_mesh build the production meshes; every
step is lowered with ShapeDtypeStruct inputs (no allocation), compiled,
and its memory_analysis / cost_analysis / collective schedule recorded
for EXPERIMENTS.md §Dry-run and §Roofline.
"""
# The VERY FIRST lines — before ANY other import (jax locks the device
# count on first init):
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config, variant_for_shape
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,512,1024]' -> bytes. Tuples handled by caller."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output bytes of every collective op in the (post-SPMD) HLO."""
    out = {c: {"count": 0, "bytes": 0.0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.:  %ag = bf16[2,16,...]{...} all-gather(...), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\{?.*?\}?\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", line)
        if not m:
            continue
        shape_part, op = m.groups()
        if shape_part.startswith("("):
            total = sum(_shape_bytes(s.strip())
                        for s in shape_part[1:-1].split(","))
        else:
            total = _shape_bytes(shape_part)
        out[op]["count"] += 1
        out[op]["bytes"] += total
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               extra: Optional[dict] = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = INPUT_SHAPES[shape_name]
    cfg = variant_for_shape(get_config(arch), shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "family": cfg.family,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "sliding_window": cfg.sliding_window}
    t0 = time.time()
    with mesh:
        if shape.kind in ("train", "prefill"):
            if shape.kind == "train":
                step, opt = S.make_train_step(cfg, mesh)
                ps = S.params_struct(cfg, mesh)
                os_ = S.opt_state_struct(cfg, mesh, opt)
                batch = S.input_specs(cfg, shape, mesh)
                lowered = jax.jit(step).lower(ps, os_, batch)
            else:
                step = S.make_prefill_step(cfg, mesh)
                ps = S.params_struct(cfg, mesh)
                batch = S.input_specs(cfg, shape, mesh)
                lowered = jax.jit(step).lower(ps, batch)
        else:  # decode
            step = S.make_serve_step(cfg, mesh)
            ps = S.params_struct(cfg, mesh)
            cache = S.cache_specs_struct(cfg, shape, mesh)
            ins = S.input_specs(cfg, shape, mesh)
            lowered = jax.jit(step).lower(ps, cache, ins["tokens"], ins["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       (k in ("flops", "bytes accessed", "optimal_seconds")
                        or k.startswith("bytes accessed"))}
        text = compiled.as_text()
        rec["collectives"] = parse_collectives(text)
        rec["hlo_len"] = len(text)
    if extra:
        rec.update(extra)
    return rec


def dryrun_hfl(arch: str) -> dict:
    """Lower the explicitly two-tier HFL step (paper mapping): per-pod
    divergent model replicas (leading pod dim sharded over `pod`), one
    edge iteration per pod, then a data-size-weighted cloud aggregation
    over the pod dimension — a REAL all-reduce over the pod axis."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh(multi_pod=True)
    n_pods = mesh.shape["pod"]
    shape = INPUT_SHAPES["train_4k"]
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": "train_4k+hfl", "mesh": "2x16x16",
           "family": cfg.family, "params": cfg.param_count(),
           "active_params": cfg.active_param_count(), "sliding_window": 0}
    t0 = time.time()
    with mesh:
        step = S.make_hfl_train_step(cfg, mesh)
        base = S.params_struct(cfg, mesh)

        def podded(x):
            spec = x.sharding.spec
            return jax.ShapeDtypeStruct(
                (n_pods,) + x.shape, x.dtype,
                sharding=NamedSharding(mesh, P("pod", *spec)))

        pp = jax.tree.map(podded, base)
        raw = S.input_specs(cfg, shape, mesh)

        def podded_batch(x):
            spec = list(x.sharding.spec)
            shp = (n_pods, x.shape[0] // n_pods) + x.shape[1:]
            return jax.ShapeDtypeStruct(
                x.shape[:0] + shp, x.dtype,
                sharding=NamedSharding(mesh, P("pod", "data", *spec[1:])))

        batch = jax.tree.map(podded_batch, raw)
        sync = jax.ShapeDtypeStruct((), jnp.bool_,
                                    sharding=NamedSharding(mesh, P()))
        lowered = jax.jit(step).lower(pp, batch, sync)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       (k in ("flops", "bytes accessed"))}
        rec["collectives"] = parse_collectives(compiled.as_text())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--hfl-step", action="store_true",
                    help="lower the explicit two-tier HFL step instead")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.hfl_step:
        assert args.arch, "--hfl-step requires --arch"
        rec = dryrun_hfl(args.arch)
        print(json.dumps(rec, indent=1))
        if os.path.exists(args.out):
            with open(args.out) as f:
                results = json.load(f)
        else:
            results = []
        key = (rec["arch"], rec["shape"], rec["mesh"])
        results = [r for r in results
                   if (r["arch"], r["shape"], r["mesh"]) != key]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        return

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    # --force re-runs the SELECTED combos but never drops other records
    done = set() if args.force else {
        (r["arch"], r["shape"], r["mesh"]) for r in results
        if "error" not in r}

    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name)
                if key in done:
                    print(f"skip {key} (cached)")
                    continue
                print(f"=== dry-run {arch} x {shape} on {mesh_name}",
                      flush=True)
                try:
                    rec = dryrun_one(arch, shape, mp)
                    c = rec["cost"]
                    print(f"    ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"flops/dev={c.get('flops', 0):.3e}", flush=True)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}"}
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_err = sum(1 for r in results if "error" in r)
    print(f"done: {len(results)} records, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
