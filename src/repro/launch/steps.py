"""Distributed step builders: train_step / serve_step + input_specs.

These are the functions the multi-pod dry-run lowers and compiles for
every (architecture x input-shape x mesh) combination, and that the real
launchers (train.py / serve.py) execute.

train_step semantics (HFL mapping):
  * the global batch is sharded over (pod, data) — each pod is an edge
    cohort, each data-axis slice a device group;
  * L_local microbatches are grad-accumulated via lax.scan (the paper's L
    local iterations fused into one lowered step);
  * gradient reduction over `data` (edge aggregation, eq. 2) happens in
    the backward pass; with `cloud_sync=True` an explicit parameter
    all-reduce over `pod` (cloud aggregation, eq. 3) is appended — in the
    faithful trainer it fires every Q steps.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T
from repro.optim import adafactor, adam
from repro.parallel.sharder import MeshSharder
from repro.parallel import sharding as shd

BIG_MODEL_PARAMS = 20e9      # adafactor above this


# ------------------------------------------------------------ input specs

def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
    shardable, no device allocation."""
    dp = shd.batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(mesh, shd.fit_spec(mesh, shp, spec)))

    if shape.kind in ("train", "prefill"):
        n_pre = cfg.n_prefix_embeds
        s_text = S - n_pre
        if cfg.family == "audio" and cfg.n_codebooks > 1:
            toks = sds((B, s_text, cfg.n_codebooks), jnp.int32, P(dp, None, None))
            labs = sds((B, s_text, cfg.n_codebooks), jnp.int32, P(dp, None, None))
        else:
            toks = sds((B, s_text), jnp.int32, P(dp, None))
            labs = sds((B, s_text), jnp.int32, P(dp, None))
        batch = {"tokens": toks, "labels": labs}
        if n_pre > 0:
            batch["prefix_embeds"] = sds((B, n_pre, cfg.d_model),
                                         cfg.compute_dtype, P(dp, None, None))
        return batch
    # decode: one token per sequence against a seq_len-deep cache
    if cfg.family == "audio" and cfg.n_codebooks > 1:
        toks = sds((B, 1, cfg.n_codebooks), jnp.int32, P(dp, None, None))
    else:
        toks = sds((B, 1), jnp.int32, P(dp, None))
    return {"tokens": toks,
            "pos": jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=NamedSharding(mesh, P()))}


def cache_specs_struct(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """Decode-cache ShapeDtypeStructs (via eval_shape — no allocation)."""
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
    shardings = shd.cache_shardings(cache_shape, cfg, mesh)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        cache_shape, shardings)


def params_struct(cfg: ModelConfig, mesh: Mesh):
    shape_tree = jax.eval_shape(
        lambda: T.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32))
    shardings = shd.param_shardings(shape_tree, cfg, mesh)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        shape_tree, shardings)


# ------------------------------------------------------------- optimizers

def make_optimizer(cfg: ModelConfig, lr: float = 1e-4):
    if cfg.param_count() > BIG_MODEL_PARAMS:
        return adafactor(lr)
    return adam(lr)


def opt_state_struct(cfg: ModelConfig, mesh: Mesh, opt):
    """Optimizer-state ShapeDtypeStructs; moments inherit the sharding of
    their parameter (matched by shape), adafactor row/col factors inherit
    the param spec with the reduced dim dropped."""
    ps = params_struct(cfg, mesh)
    st = jax.eval_shape(opt.init, ps)
    flat_p = jax.tree.leaves(ps)
    specs_p = jax.tree.leaves(shd.param_specs(ps, cfg, mesh))
    by_shape = {}
    for leaf, spec in zip(flat_p, specs_p):
        by_shape.setdefault(leaf.shape, spec)

    def assign(x):
        spec = by_shape.get(x.shape, P())
        # factored adafactor rows/cols: reuse the param spec minus last dim
        if x.shape not in by_shape:
            for pshape, pspec in by_shape.items():
                if x.shape == pshape[:-1]:
                    spec = P(*list(pspec)[:-1])
                    break
                if len(pshape) >= 2 and x.shape == pshape[:-2] + pshape[-1:]:
                    ent = list(pspec) + [None] * (len(pshape) - len(pspec))
                    spec = P(*(ent[:-2] + ent[-1:]))
                    break
        return jax.ShapeDtypeStruct(
            x.shape, x.dtype,
            sharding=NamedSharding(mesh, shd.fit_spec(mesh, x.shape, spec)))

    return jax.tree.map(assign, st)


# -------------------------------------------------------------- steps

def make_train_step(cfg: ModelConfig, mesh: Mesh, *, lr: float = 1e-4,
                    cloud_sync: Optional[bool] = None, impl: str = "xla"):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    cloud_sync defaults to True iff the mesh has a pod axis (so the
    lowered artifact exhibits the full two-tier HFL collective pattern).
    """
    multi_pod = "pod" in mesh.axis_names
    if cloud_sync is None:
        cloud_sync = multi_pod
    opt = make_optimizer(cfg, lr)
    sharder = MeshSharder(mesh, shd.act_rules(cfg, mesh))
    mb = max(1, cfg.microbatches)

    def train_step(params, opt_state, batch):
        def loss_of(p, microbatch):
            # pin params at use: with_sharding_constraint transposes to a
            # constraint on the cotangent, keeping per-layer grads sharded
            # instead of all-gathered full (3.5 GB/leaf f32 for
            # llama3-405b before the fix; §Perf iteration 3). [A casting-
            # to-bf16-here variant was tried and REFUTED: identical
            # collective bytes, +4 GB temp — XLA already gathers bf16
            # inside the layer loop; see §Perf iteration 4.]
            p = jax.tree.map(jax.lax.with_sharding_constraint, p,
                             shd.param_shardings(p, cfg, mesh))
            loss, metrics = T.loss_fn(p, microbatch, cfg, sharder=sharder,
                                      impl=impl)
            return loss, metrics

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        # pin the grad-accumulation carry to the parameter shardings —
        # without the constraint XLA materialises REPLICATED f32 grads
        # (3.5 GB/leaf for llama3-405b; §Perf iteration 3)
        pshard = shd.param_shardings(params, cfg, mesh)
        def pin(tree):
            return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                                pshard)

        def accum(carry, mb_batch):
            g_acc, l_acc = carry
            (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb_batch)
            return (pin(jax.tree.map(jnp.add, g_acc, g)), l_acc + loss), None

        g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))
        (grads, loss_sum), _ = jax.lax.scan(
            accum, (g0, 0.0), micro,
            unroll=mb if cfg.unroll_layers else 1)
        grads = jax.tree.map(lambda g: g / mb, grads)
        # edge aggregation (eq. 2) = the grad all-reduce over `data`;
        # with the batch also sharded over `pod`, the same reduction spans
        # the pod axis — the multi-pod dry-run proves that axis shards.
        # The *explicitly two-tier* variant (divergent per-pod replicas,
        # Q-periodic cloud sync) is make_hfl_train_step below.
        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = {"loss": loss_sum / mb}
        return new_params, new_opt, metrics

    return train_step, opt


def make_hfl_train_step(cfg: ModelConfig, mesh: Mesh, *, lr: float = 1e-4,
                        impl: str = "xla"):
    """Paper-faithful two-tier step: every pod (edge cohort) holds its OWN
    model replica (leading pod dim, sharded over `pod`); the step runs one
    edge iteration per pod and then cloud-aggregates (eq. 3) with an
    explicit data-size-weighted mean over the pod dimension — which lowers
    to a real all-reduce/all-gather over the pod axis.

    params leaves: (n_pods, ...) sharded P("pod", <param spec>).
    batch leaves:  (n_pods, B/pods, ...) sharded P("pod", "data", ...).
    """
    assert "pod" in mesh.axis_names, "hfl step needs the multi-pod mesh"
    n_pods = mesh.shape["pod"]
    sharder = MeshSharder(mesh, shd.act_rules(cfg, mesh))
    mb = max(1, cfg.microbatches)

    def one_pod_step(params, batch):
        def loss_of(p, microbatch):
            return T.loss_fn(p, microbatch, cfg, impl=impl)[0]

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def accum(g_acc, mb_batch):
            g = jax.grad(loss_of)(params, mb_batch)
            return jax.tree.map(jnp.add, g_acc, g), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, _ = jax.lax.scan(accum, g0, micro)
        return jax.tree.map(lambda p, g: p - lr * g / mb, params, grads)

    def hfl_train_step(pod_params, batch, do_cloud_sync):
        new_pp = jax.vmap(one_pod_step)(pod_params, batch)
        # cloud aggregation: mean over the pod dim (all-reduce over `pod`)
        synced = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                       x.shape), new_pp)
        def pick(a, b):
            return jnp.where(do_cloud_sync, a, b)
        return jax.tree.map(pick, synced, new_pp)

    return hfl_train_step


def make_serve_step(cfg: ModelConfig, mesh: Mesh):
    """serve_step(params, cache, tokens, pos) -> (logits, cache)."""
    sharder = MeshSharder(mesh, shd.act_rules(cfg, mesh))

    def serve_step(params, cache, tokens, pos):
        return T.decode(params, tokens, cache, pos, cfg, sharder=sharder)

    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, impl: str = "xla"):
    sharder = MeshSharder(mesh, shd.act_rules(cfg, mesh))

    def prefill_step(params, batch):
        logits, _ = T.forward(params, batch, cfg, sharder=sharder, impl=impl)
        return logits

    return prefill_step
