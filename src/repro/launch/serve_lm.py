"""Batched LM decode serving CLI (formerly ``repro.launch.serve``;
that name now hosts the streaming async-HFL service).

    PYTHONPATH=src python -m repro.launch.serve_lm --arch mistral-nemo-12b \
        --smoke --batch 8 --prompt-len 32 --gen 64

Prefills a random prompt batch, then decodes `gen` tokens per sequence
through the jitted serve_step (KV/SSM cache), reporting tokens/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.launch import steps as S
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_debug_mesh())
    key = jax.random.PRNGKey(args.seed)
    max_len = args.prompt_len + args.gen

    with mesh:
        params = T.init(key, cfg)
        serve = jax.jit(S.make_serve_step(cfg, mesh))
        cache = T.init_cache(cfg, args.batch, max_len)
        tok_shape = ((args.batch, args.prompt_len) if cfg.n_codebooks == 1
                     else (args.batch, args.prompt_len, cfg.n_codebooks))
        prompt = jax.random.randint(key, tok_shape, 0, cfg.vocab_size)

        # prefill through the decode path (teacher-forced)
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, cache = serve(params, cache, prompt[:, t:t + 1],
                                  jnp.int32(t))
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        def sample(logits, k):
            lg = logits[:, 0]
            if cfg.n_codebooks > 1:
                lg = lg.reshape(args.batch, cfg.n_codebooks, cfg.vocab_size)
            if args.temperature <= 0:
                nxt = jnp.argmax(lg, axis=-1)
            else:
                nxt = jax.random.categorical(k, lg / args.temperature, axis=-1)
            return nxt.astype(jnp.int32)

        out_tokens = []
        t0 = time.time()
        cur = sample(logits, key)
        for t in range(args.prompt_len, max_len):
            cur_in = cur[:, None] if cfg.n_codebooks == 1 else cur[:, None, :]
            logits, cache = serve(params, cache, cur_in, jnp.int32(t))
            key, sk = jax.random.split(key)
            cur = sample(logits, sk)
            out_tokens.append(np.asarray(cur))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    tps = args.batch * args.gen / t_decode
    print(f"arch={cfg.name} batch={args.batch} prefill={t_prefill:.2f}s "
          f"decode={t_decode:.2f}s ({tps:,.1f} tok/s)")
    arr = np.stack(out_tokens, axis=1)
    k = min(16, arr.shape[1])
    print(f"sample tokens[0,:{k}]:",
          arr[0, :k].reshape(k, -1)[:, 0].tolist())


if __name__ == "__main__":
    main()
