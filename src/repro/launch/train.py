"""Distributed trainer CLI.

    PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --smoke \
        --steps 200 --batch 16 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container the debug mesh (1 device) is used automatically and
``--smoke`` selects the reduced config; on a real TPU slice the production
mesh from ``repro.launch.mesh`` drives the same code path.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import token_batch_iterator
from repro.launch import steps as S
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import transformer as T
from repro.utils import tree_size


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.microbatches:
        cfg = dataclasses.replace(cfg, microbatches=args.microbatches)
    mesh = (make_production_mesh() if args.production_mesh
            else make_debug_mesh())
    print(f"arch={cfg.name} params={tree_size(jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg)))/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}", flush=True)

    with mesh:
        step_fn, opt = S.make_train_step(cfg, mesh, lr=args.lr)
        params = T.init(jax.random.PRNGKey(args.seed), cfg)
        opt_state = opt.init(params)
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            start = latest_step(args.ckpt_dir)
            params = restore_pytree(params, args.ckpt_dir)
            print(f"restored step {start}", flush=True)
        step_j = jax.jit(step_fn, donate_argnums=(0, 1))

        it = token_batch_iterator(cfg.vocab_size, args.batch, args.seq,
                                  seed=args.seed)
        t0 = time.time()
        tokens_seen = 0
        for i in range(start + 1, args.steps + 1):
            raw = next(it)
            batch = {"tokens": jnp.asarray(raw["tokens"]),
                     "labels": jnp.asarray(raw["labels"])}
            if cfg.n_prefix_embeds:
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_prefix_embeds, cfg.d_model),
                    cfg.compute_dtype)
            if cfg.n_codebooks > 1:
                batch["tokens"] = jnp.broadcast_to(
                    batch["tokens"][..., None],
                    batch["tokens"].shape + (cfg.n_codebooks,))
                batch["labels"] = jnp.broadcast_to(
                    batch["labels"][..., None],
                    batch["labels"].shape + (cfg.n_codebooks,))
            params, opt_state, metrics = step_j(params, opt_state, batch)
            tokens_seen += args.batch * args.seq
            if i % args.log_every == 0:
                loss = float(metrics["loss"])
                tps = tokens_seen / (time.time() - t0)
                print(f"step {i:5d} loss={loss:.4f} tok/s={tps:,.0f}",
                      flush=True)
            if args.ckpt_dir and i % args.ckpt_every == 0:
                save_pytree(params, args.ckpt_dir, i)
        if args.ckpt_dir:
            save_pytree(params, args.ckpt_dir, args.steps)
    print("done", flush=True)


if __name__ == "__main__":
    main()
