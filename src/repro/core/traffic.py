"""Synthetic fleet traffic — Poisson joins, diurnal load, burst mode.

Feeds the async engine (``core/async_engine.py``) with availability
traces shaped like a real IoT fleet's day instead of the stationary
alternating-renewal process of ``cost_model.sample_availability``:
device *joins* arrive as a non-homogeneous Poisson process with rate

    lam(t) = join_rate * (1 + diurnal_amp * sin(2*pi*t / diurnal_period))
                       * (burst_mult inside burst windows)

sampled by thinning against the rate envelope, and each join keeps the
device online for an Exp(mean_session_s) session. The output is a plain
:class:`repro.core.cost_model.AvailabilityTrace`, so the engine (and its
parity contract) is agnostic to where a trace came from.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import cost_model as cm


@dataclasses.dataclass(frozen=True)
class TrafficParams:
    """Traffic-shape knobs; defaults are a mild stationary fleet."""
    join_rate: float = 0.1          # fleet-wide joins / virtual second
    mean_session_s: float = 300.0   # online duration after a join
    diurnal_amp: float = 0.0        # 0..1 sinusoidal rate modulation
    diurnal_period_s: float = 3600.0
    burst_mult: float = 1.0         # rate multiplier inside bursts
    burst_every_s: float = math.inf  # burst window cadence
    burst_len_s: float = 0.0        # burst window length
    p_online0: float = 1.0          # fraction online at t=0


class TrafficGenerator:
    """Builds availability traces from a :class:`TrafficParams` shape."""

    def __init__(self, params: TrafficParams, n_devices: int,
                 seed: int = 0):
        self.params, self.n, self.seed = params, n_devices, seed

    def rate(self, t: float) -> float:
        """Instantaneous fleet join rate lam(t) [joins/s]."""
        tp = self.params
        lam = tp.join_rate * (1.0 + tp.diurnal_amp
                              * math.sin(2.0 * math.pi * t
                                         / tp.diurnal_period_s))
        if (math.isfinite(tp.burst_every_s) and tp.burst_len_s > 0
                and t % tp.burst_every_s < tp.burst_len_s):
            lam *= tp.burst_mult
        return max(lam, 0.0)

    def make_trace(self, horizon_s: float,
                   ap: Optional[cm.AvailabilityParams] = None
                   ) -> cm.AvailabilityTrace:
        """Simulate joins/leaves over ``[0, horizon_s]``.

        Joins are thinned against the constant envelope
        ``join_rate * (1+diurnal_amp) * burst_mult``; each join flips a
        uniformly chosen offline device online for an Exp-length
        session. ``ap`` (optional) supplies straggler latency scales via
        the jit-compatible cost-model sampler.
        """
        tp, n = self.params, self.n
        rng = np.random.default_rng(self.seed)
        online = rng.uniform(size=n) < tp.p_online0
        # devices online at t=0 leave after one session length
        toggles = [[] for _ in range(n)]
        leave_t = np.full(n, np.inf)
        leave_t[online] = rng.exponential(tp.mean_session_s,
                                          int(online.sum()))
        init_up = online.copy()

        env = tp.join_rate * (1.0 + max(tp.diurnal_amp, 0.0)) \
            * max(tp.burst_mult, 1.0)
        t = 0.0
        while True:
            # next candidate join (homogeneous envelope), next leave
            t_join = (t + rng.exponential(1.0 / env)
                      if env > 0 else math.inf)
            t_leave = leave_t.min()
            t = min(t_join, t_leave)
            if t > horizon_s:
                break
            if t_leave <= t_join:
                d = int(leave_t.argmin())
                online[d] = False
                leave_t[d] = np.inf
                toggles[d].append(t)
                continue
            if rng.uniform() * env > self.rate(t):
                continue             # thinned: envelope candidate rejected
            off = np.flatnonzero(~online)
            if len(off) == 0:
                continue             # whole fleet already online
            d = int(rng.choice(off))
            online[d] = True
            leave_t[d] = t + rng.exponential(tp.mean_session_s)
            toggles[d].append(t)

        width = max(1, max(len(row) for row in toggles))
        tog = np.full((n, width), np.inf)
        for d, row in enumerate(toggles):
            tog[d, :len(row)] = row
        scale = np.ones(n)
        if ap is not None and ap.straggler_frac > 0:
            import jax
            scale = np.asarray(cm.sample_straggler_scales(
                jax.random.PRNGKey(self.seed), ap, n), np.float64)
        return cm.AvailabilityTrace(init_up=init_up, toggles=tog,
                                    latency_scale=scale)
