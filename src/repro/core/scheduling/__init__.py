from repro.core.scheduling.schedulers import (  # noqa: F401
    FedAvgScheduler, VKCScheduler, IKCScheduler, Scheduler,
    SerialFedAvgScheduler, SerialVKCScheduler, SerialIKCScheduler)
from repro.core.scheduling.device_clustering import (  # noqa: F401
    run_device_clustering, auxiliary_weight_vectors)
