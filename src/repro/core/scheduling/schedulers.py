"""Device scheduling policies: FedAvg-random, VKC (Alg. 3), IKC (Alg. 4).

All schedulers expose ``schedule(rng) -> np.ndarray[H]`` of device indices
and ``topup_to(selected, target, rng)`` (Alg. 3 lines 12-15 / Alg. 4 lines
21-24 — used by ``SweepRunner`` when a lane comes up short of the
lane-wide cohort shape).

Two engines per policy, PR-1..5 style:

* ``FedAvgScheduler`` / ``VKCScheduler`` / ``IKCScheduler`` — the default
  vectorized state machines. Cluster membership lives in one flat CSR
  index array (``_ClusterState``: member ids grouped by cluster + row
  offsets + a device->slot position index — the dense equivalent of a
  ``(K, max_cluster)`` padded panel without its K*N blow-up on skewed
  clusterings), and a round is O(H log h) array ops: per-cluster
  sampling is a vectorized rejection draw (large clusters) or a
  masked-argsort permutation (small clusters), rotation-set transfer is
  an in-place window swap, and top-up is a rejection draw from the
  unscheduled pool. Nothing per-round touches O(N) state, so scheduling
  at N=10^5 costs the same as at N=10^3 for a fixed cohort
  (``benchmarks/bench_schedule_scale.py``).
* ``SerialFedAvgScheduler`` / ``SerialVKCScheduler`` /
  ``SerialIKCScheduler`` — the original per-cluster Python-list
  implementations, kept verbatim as distribution oracles for the parity
  suite (``tests/test_scheduling.py``).

State (IKC's per-cluster rotation sets G_k) lives on the scheduler
object, exactly mirroring the paper's set-transfer semantics. Devices
scheduled via top-up are recorded into their owning cluster's rotation
set in BOTH engines — a topped-up device must not be re-picked before
its cluster-mates are scheduled once (Alg. 4's no-repeat invariant; the
pre-fix code left top-up picks in C_k).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


class Scheduler:
    n_devices: int

    def schedule(self, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def topup_to(self, selected, target: int, rng) -> np.ndarray:
        """Top ``selected`` up to ``target`` devices from the unscheduled
        pool (uniform, without replacement). Policies with rotation state
        override this to record the extra picks."""
        return np.asarray(_topup(list(np.asarray(selected, dtype=np.int64)),
                                 self.n_devices, target, rng),
                          dtype=np.int64)


def _topup(selected: List[int], n_devices: int, target: int, rng
           ) -> List[int]:
    """Alg.3 lines 12-15 / Alg.4 lines 21-24: random devices from the
    unscheduled pool until |H_i| == target. O(N) setdiff — the serial
    oracle's path; the vectorized schedulers use ``_sample_excluding``."""
    if len(selected) < target:
        pool = np.setdiff1d(np.arange(n_devices), np.asarray(selected, int))
        extra = rng.choice(pool, target - len(selected), replace=False)
        selected = selected + list(extra)
    return selected


# --------------------------------------------------------------------------
# serial oracles (the original list-based engines)
# --------------------------------------------------------------------------


class SerialFedAvgScheduler(Scheduler):
    """[3]: uniformly random H devices per round (serial oracle)."""

    def __init__(self, n_devices: int, H: int):
        self.n_devices = n_devices
        self.H = H

    def schedule(self, rng) -> np.ndarray:
        return rng.choice(self.n_devices, self.H, replace=False)


class SerialVKCScheduler(Scheduler):
    """Algorithm 3 — vanilla K-Center: h random devices per cluster
    (serial oracle)."""

    def __init__(self, clusters: Sequence[int], h: int):
        clusters = np.asarray(clusters)
        self.n_devices = len(clusters)
        self.K = int(clusters.max()) + 1
        self.h = h
        self.members = [np.flatnonzero(clusters == k) for k in range(self.K)]

    @property
    def H(self) -> int:
        return self.h * self.K

    def schedule(self, rng) -> np.ndarray:
        sel: List[int] = []
        for k in range(self.K):
            ck = self.members[k]
            if len(ck) >= self.h:                       # line 7
                sel += list(rng.choice(ck, self.h, replace=False))
            else:                                       # line 9
                sel += list(ck)
        sel = _topup(sel, self.n_devices, self.H, rng)
        return np.asarray(sel)


class SerialIKCScheduler(Scheduler):
    """Algorithm 4 — improved K-Center with per-cluster rotation sets G_k
    (serial oracle).

    C_k = not-recently-scheduled members, G_k = recently scheduled. Fresh
    devices are preferred; when C_k runs dry it is refilled from G_k,
    guaranteeing every cluster member is scheduled before any repeats.
    Top-up picks are recorded into their cluster's G_k so the invariant
    also holds across the Alg.-4 line 21-24 path.
    """

    def __init__(self, clusters: Sequence[int], h: int):
        clusters = np.asarray(clusters)
        self.clusters = clusters
        self.n_devices = len(clusters)
        self.K = int(clusters.max()) + 1
        self.h = h
        self.C = [list(np.flatnonzero(clusters == k)) for k in range(self.K)]
        self.G: List[List[int]] = [[] for _ in range(self.K)]

    @property
    def H(self) -> int:
        return self.h * self.K

    def schedule(self, rng) -> np.ndarray:
        sel: List[int] = []
        for k in range(self.K):
            Ck, Gk, h = self.C[k], self.G[k], self.h
            if len(Ck) + len(Gk) >= h:
                if len(Ck) >= h:                        # line 9
                    pick = list(rng.choice(Ck, h, replace=False))
                    self.C[k] = [d for d in Ck if d not in pick]
                    self.G[k] = Gk + pick
                else:                                   # lines 11-14
                    pick = list(Ck)
                    need = h - len(pick)
                    from_g = list(rng.choice(Gk, need, replace=False))
                    pick += from_g
                    remaining = [d for d in Gk if d not in from_g]
                    self.C[k] = remaining               # line 13
                    self.G[k] = list(pick)              # line 14
                sel += pick
            else:                                       # line 17
                sel += list(Ck) + list(Gk)
        return self.topup_to(np.asarray(sel, dtype=np.int64), self.H, rng)

    def topup_to(self, selected, target: int, rng) -> np.ndarray:
        """Alg.-4 top-up that keeps the rotation invariant: draw from the
        not-yet-rotated devices (any cluster's C_k) first, fall back to
        the general pool only once every fresh device is scheduled, and
        record each pick into its cluster's G_k."""
        selected = [int(d) for d in np.asarray(selected, dtype=np.int64)]
        need = target - len(selected)
        if need <= 0:
            return np.asarray(selected, dtype=np.int64)
        sel_set = set(selected)
        fresh = [d for k in range(self.K) for d in self.C[k]
                 if d not in sel_set]
        pick: List[int] = []
        if fresh:
            pick += [int(d) for d in rng.choice(
                np.asarray(fresh), min(need, len(fresh)), replace=False)]
        if len(pick) < need:
            pool = np.setdiff1d(np.arange(self.n_devices),
                                np.asarray(selected + pick, int))
            pick += [int(d) for d in rng.choice(pool, need - len(pick),
                                                replace=False)]
        for d in pick:
            k = int(self.clusters[d])
            if d in self.C[k]:                          # record the pick
                self.C[k].remove(d)
                self.G[k].append(d)
        return np.asarray(selected + pick, dtype=np.int64)


# --------------------------------------------------------------------------
# vectorized engines
# --------------------------------------------------------------------------


def _in_sorted(vals: np.ndarray, sorted_arr: np.ndarray) -> np.ndarray:
    """Membership of ``vals`` in a sorted array, O(|vals| log |sorted|)."""
    if len(sorted_arr) == 0:
        return np.zeros(len(vals), dtype=bool)
    idx = np.minimum(np.searchsorted(sorted_arr, vals), len(sorted_arr) - 1)
    return sorted_arr[idx] == vals


def _sample_excluding(rng, n: int, size: int,
                      exclude_sorted: np.ndarray) -> np.ndarray:
    """``size`` distinct uniform draws from [0, n) minus a sorted exclude
    set, in O(size log size) expected — the O(scheduled) replacement for
    the serial ``setdiff1d`` top-up pool.

    Rejection sampling: draw batches, drop excluded/duplicate values,
    keep a uniform random subset once enough survive (any scheme that is
    symmetric under relabelling of the pool yields a uniform
    without-replacement sample). Falls back to materializing the pool
    when the pool is under half of [0, n) or the request covers most of
    it — there the O(n) pass is O(size) anyway.
    """
    pool = n - len(exclude_sorted)
    if size > pool:
        raise ValueError(f"cannot draw {size} devices from a pool of {pool}")
    if size == 0:
        return np.empty(0, dtype=np.int64)
    if 2 * size > pool or 2 * pool < n:
        full = np.setdiff1d(np.arange(n), exclude_sorted)
        return rng.choice(full, size, replace=False).astype(np.int64)
    chosen = np.empty(0, dtype=np.int64)
    for _ in range(64):
        need = size - len(chosen)
        if need <= 0:
            break
        cand = rng.integers(0, n, 2 * need + 8)
        cand = cand[~_in_sorted(cand, exclude_sorted)]
        chosen = np.union1d(chosen, cand)
    else:  # pragma: no cover - pool >= 2*size makes this unreachable
        raise RuntimeError("rejection sampling failed to converge")
    if len(chosen) > size:
        chosen = rng.choice(chosen, size, replace=False)
    return chosen.astype(np.int64)


def _ragged_gather(flat: np.ndarray, starts: np.ndarray,
                   counts: np.ndarray) -> np.ndarray:
    """Concatenate ``flat[starts[r] : starts[r]+counts[r]]`` for all rows
    without a per-row Python loop."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    offs = np.cumsum(counts) - counts
    return flat[np.repeat(starts - offs, counts) + np.arange(total)]


class _ClusterState:
    """Vectorized cluster membership state shared by VKC/IKC.

    ``order`` is the flat CSR member array: ``order[offsets[k]:
    offsets[k+1]]`` holds cluster k's device ids in arbitrary order (one
    O(N) build at construction — the same information as a
    ``(K, max_cluster)`` padded index panel, minus the K*N worst case).
    ``pos`` inverts it (device id -> flat slot) so rotation bookkeeping
    can move an individual device in O(1). All per-round mutation goes
    through ``pick_tail`` — uniform without-replacement sampling inside
    per-cluster windows with the picked members swapped to each window's
    tail — which is what makes the schedulers' rotation-set transfer a
    boundary shift instead of list surgery.
    """

    #: windows at least this many times larger than the pick count use
    #: the rejection path; smaller windows are cheaper to fully permute.
    _REJECT_FACTOR = 8

    def __init__(self, clusters: Sequence[int]):
        clusters = np.asarray(clusters, dtype=np.int64)
        self.clusters = clusters
        self.n_devices = len(clusters)
        self.K = int(clusters.max()) + 1
        self.counts = np.bincount(clusters, minlength=self.K)
        self.offsets = np.concatenate(
            [np.zeros(1, dtype=np.int64), np.cumsum(self.counts)])
        self.order = np.argsort(clusters, kind="stable").astype(np.int64)
        self.pos = np.empty(self.n_devices, dtype=np.int64)
        self.pos[self.order] = np.arange(self.n_devices)

    def pick_tail(self, rng, rows: np.ndarray, sizes: np.ndarray,
                  n_pick: np.ndarray) -> None:
        """For each row r (ascending cluster ids), move ``n_pick[r]``
        uniformly-chosen members of the window ``[offsets[r],
        offsets[r]+sizes[r])`` into the window's tail slots, in place.

        O(total picked · log) with no per-row Python: big windows draw
        candidate slots with replacement, keep a random subset of the
        distinct ones (uniform by symmetry) and repair the tail with a
        searchsorted membership pass; small windows (< _REJECT_FACTOR ×
        pick) are fully permuted through one masked-argsort batch.
        """
        keep = n_pick > 0
        rows, sizes, n_pick = rows[keep], sizes[keep], n_pick[keep]
        if len(rows) == 0:
            return
        big = sizes >= self._REJECT_FACTOR * n_pick
        if big.any():
            self._pick_tail_reject(rng, self.offsets[rows[big]], sizes[big],
                                   n_pick[big])
        if (~big).any():
            self._pick_tail_permute(rng, self.offsets[rows[~big]],
                                    sizes[~big], n_pick[~big])

    def _pick_tail_reject(self, rng, base, sz, n_pick):
        # all big rows request the same count (a row with a smaller
        # natural pick, n_pick = window size, can never be 8x smaller
        # than its own window)
        h = int(n_pick.max())
        assert (n_pick == h).all()
        R, D = len(base), 2 * h + 8
        P = np.empty((R, h), dtype=np.int64)
        pending = np.arange(R)
        for _ in range(64):
            if len(pending) == 0:
                break
            cand = rng.integers(0, sz[pending, None], (len(pending), D))
            cand.sort(axis=1)
            first = np.ones(cand.shape, dtype=bool)
            first[:, 1:] = cand[:, 1:] != cand[:, :-1]
            ok = first.sum(axis=1) >= h
            keys = rng.random(cand.shape)
            keys[~first] = np.inf                  # duplicates never chosen
            sel = np.argsort(keys, axis=1)[:, :h]  # random h of the distinct
            P[pending[ok]] = np.take_along_axis(cand, sel, axis=1)[ok]
            pending = pending[~ok]
        else:  # pragma: no cover - sz >= 8h makes this unreachable
            raise RuntimeError("slot sampling failed to converge")
        P.sort(axis=1)
        # repair: picked values go to the tail window, tail values that
        # were not picked back-fill the holes the picked ones left.
        Pa = (P + base[:, None]).ravel()           # globally sorted: rows
        tail = (sz[:, None] - h + np.arange(h)[None, :] + base[:, None])
        ta = tail.ravel()                          # are disjoint ascending
        in_p = _in_sorted(ta, Pa)
        holes = Pa[(P < (sz - h)[:, None]).ravel()]
        fillers = ta[~in_p]                        # row-major on both sides,
        vals_p = self.order[Pa]                    # per-row counts match
        filler_vals = self.order[fillers]
        self.order[holes] = filler_vals
        self.order[ta] = vals_p
        self.pos[filler_vals] = holes
        self.pos[vals_p] = ta

    def _pick_tail_permute(self, rng, base, sz, n_pick):
        W = int(sz.max())
        cols = np.arange(W)[None, :]
        valid = cols < sz[:, None]
        idx = base[:, None] + np.minimum(cols, sz[:, None] - 1)
        vals = self.order[idx]
        keys = rng.random((len(base), W))
        keys[~valid] = np.inf                      # pad lanes sort last
        perm = np.argsort(keys, axis=1)
        new_vals = np.take_along_axis(vals, perm, axis=1)[valid]
        tgt = (base[:, None] + cols)[valid]
        self.order[tgt] = new_vals
        self.pos[new_vals] = tgt

    def refill_row(self, rng, k: int, nf_k: int, h: int) -> None:
        """Alg. 4 lines 11-14 for one cluster: pick = all of C_k plus
        h - |C_k| random members of G_k; the row is rebuilt as
        [G_k \\ picked | picked] so the new C_k is the survivors and the
        new G_k (the window tail) is exactly the pick. O(|cluster|),
        amortized O(h) per round (a cluster refills once per rotation).
        """
        base, cnt = int(self.offsets[k]), int(self.counts[k])
        row = self.order[base:base + cnt]
        fresh = row[:nf_k].copy()
        g = row[nf_k:].copy()
        smask = np.zeros(len(g), dtype=bool)
        smask[rng.choice(len(g), h - nf_k, replace=False)] = True
        new_row = np.concatenate([g[~smask], fresh, g[smask]])
        self.order[base:base + cnt] = new_row
        self.pos[new_row] = base + np.arange(cnt)


class FedAvgScheduler(Scheduler):
    """[3]: uniformly random H devices per round — O(H) rejection draw
    (the full-permutation path only when H exceeds half the population,
    where O(N) is O(H))."""

    def __init__(self, n_devices: int, H: int):
        self.n_devices = n_devices
        self.H = H

    def schedule(self, rng) -> np.ndarray:
        return _sample_excluding(rng, self.n_devices, self.H,
                                 np.empty(0, dtype=np.int64))

    def topup_to(self, selected, target: int, rng) -> np.ndarray:
        selected = np.asarray(selected, dtype=np.int64)
        if len(selected) >= target:
            return selected
        extra = _sample_excluding(rng, self.n_devices,
                                  target - len(selected), np.sort(selected))
        return np.concatenate([selected, extra])


class VKCScheduler(Scheduler):
    """Algorithm 3 — vanilla K-Center: h random devices per cluster
    (every member when a cluster is smaller than h), vectorized."""

    def __init__(self, clusters: Sequence[int], h: int):
        self.state = _ClusterState(clusters)
        self.n_devices = self.state.n_devices
        self.K = self.state.K
        self.h = h

    @property
    def H(self) -> int:
        return self.h * self.K

    def schedule(self, rng) -> np.ndarray:
        st = self.state
        n_pick = np.minimum(st.counts, self.h)           # lines 7 / 9
        st.pick_tail(rng, np.arange(st.K), st.counts, n_pick)
        sel = _ragged_gather(st.order, st.offsets[:-1] + st.counts - n_pick,
                             n_pick)
        if len(sel) < self.H:                            # lines 12-15
            sel = self.topup_to(sel, self.H, rng)
        return sel

    def topup_to(self, selected, target: int, rng) -> np.ndarray:
        selected = np.asarray(selected, dtype=np.int64)
        if len(selected) >= target:
            return selected
        extra = _sample_excluding(rng, self.n_devices,
                                  target - len(selected), np.sort(selected))
        return np.concatenate([selected, extra])


class IKCScheduler(Scheduler):
    """Algorithm 4 — improved K-Center with per-cluster rotation sets G_k,
    vectorized.

    Cluster k's CSR window is split by ``nf[k]``: the first nf[k] slots
    are C_k (fresh), the rest G_k (recently scheduled). A normal round
    swaps h fresh picks across the boundary (``pick_tail`` + nf -= h); a
    dry C_k refills from G_k (``refill_row``); clusters smaller than h
    contribute every member with no state change; and top-up picks are
    recorded by moving the device across its own cluster's boundary —
    every cluster member is scheduled once before any repeats, including
    through the top-up path.
    """

    def __init__(self, clusters: Sequence[int], h: int):
        self.state = _ClusterState(clusters)
        self.n_devices = self.state.n_devices
        self.K = self.state.K
        self.h = h
        self.nf = self.state.counts.copy()               # all fresh at t=0

    @property
    def H(self) -> int:
        return self.h * self.K

    def schedule(self, rng) -> np.ndarray:
        st, h = self.state, self.h
        cnt = st.counts
        short = cnt < h                                  # line 17
        normal = ~short & (self.nf >= h)                 # line 9
        rows = np.flatnonzero(normal)
        st.pick_tail(rng, rows, self.nf[rows],
                     np.full(len(rows), h, dtype=np.int64))
        self.nf[rows] -= h
        for k in np.flatnonzero(~short & (self.nf < h) & ~normal):
            st.refill_row(rng, int(k), int(self.nf[k]), h)   # lines 11-14
            self.nf[k] = cnt[k] - h
        # every non-short row's pick now sits at [nf, nf + h); short rows
        # contribute their whole window.
        starts = st.offsets[:-1] + np.where(short, 0, self.nf)
        sel = _ragged_gather(st.order, starts, np.where(short, cnt, h))
        if len(sel) < self.H:                            # lines 21-24
            sel = self.topup_to(sel, self.H, rng)
        return sel

    def topup_to(self, selected, target: int, rng) -> np.ndarray:
        """Alg.-4 top-up that keeps the rotation invariant: draw from the
        not-yet-rotated devices (any cluster's C_k window) first, fall
        back to the general pool only once every fresh device is
        scheduled, and record each pick into its cluster's G_k.
        O(picked log K) via rank sampling over the fresh windows."""
        selected = np.asarray(selected, dtype=np.int64)
        t = target - len(selected)
        if t <= 0:
            return selected
        extra = self._draw_fresh(rng, t, np.sort(selected))
        self._record_scheduled(extra)
        if len(extra) < t:
            exclude = np.sort(np.concatenate([selected, extra]))
            more = _sample_excluding(rng, self.n_devices, t - len(extra),
                                     exclude)
            self._record_scheduled(more)    # no-op: nothing fresh is left
            extra = np.concatenate([extra, more])
        return np.concatenate([selected, extra])

    def _draw_fresh(self, rng, t: int, sel_sorted: np.ndarray) -> np.ndarray:
        """Up to ``t`` distinct uniform draws from the union of the fresh
        windows minus the already-selected devices."""
        st = self.state
        F = int(self.nf.sum())
        if F == 0:
            return np.empty(0, dtype=np.int64)
        k_sel = st.clusters[sel_sorted]
        rel = st.pos[sel_sorted] - st.offsets[k_sel]
        avail = F - int((rel < self.nf[k_sel]).sum())
        take = min(t, avail)
        if take == 0:
            return np.empty(0, dtype=np.int64)
        if 2 * take > avail or avail <= 64:
            # near-exhausted rotation: materialize the fresh windows —
            # O(F), and F is O(selected + take) in this regime
            fresh = _ragged_gather(st.order, st.offsets[:-1], self.nf)
            pool = fresh[~_in_sorted(fresh, sel_sorted)]
            return rng.choice(pool, take, replace=False).astype(np.int64)
        cum_hi = np.cumsum(self.nf)
        cum_lo = cum_hi - self.nf
        got = np.empty(0, dtype=np.int64)
        for _ in range(64):
            need = take - len(got)
            if need <= 0:
                break
            ranks = rng.integers(0, F, 2 * need + 8)
            kk = np.searchsorted(cum_hi, ranks, side="right")
            d = st.order[st.offsets[kk] + (ranks - cum_lo[kk])]
            got = np.union1d(got, d[~_in_sorted(d, sel_sorted)])
        else:  # pragma: no cover - avail >= 2*take makes this unreachable
            raise RuntimeError("fresh-pool sampling failed to converge")
        if len(got) > take:
            got = rng.choice(got, take, replace=False)
        return got.astype(np.int64)

    def _record_scheduled(self, devs: np.ndarray) -> None:
        """Move freshly top-upped devices from C_k into G_k (devices that
        are already in G_k stay put). O(1) per device via ``pos``."""
        st = self.state
        for d in devs:
            p = int(st.pos[d])
            k = int(st.clusters[d])
            rel = p - st.offsets[k]
            if rel < self.nf[k]:
                last = int(st.offsets[k] + self.nf[k] - 1)
                other = int(st.order[last])
                st.order[last], st.order[p] = d, other
                st.pos[d], st.pos[other] = last, p
                self.nf[k] -= 1


# --------------------------------------------------------------------------
# traced scheduler (fused sweep scan)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TracedFedAvg:
    """In-scan FedAvg scheduler for the fused sweep engine.

    The host schedulers above are numpy state machines, so a fused
    R-round ``lax.scan`` cannot call them mid-trace; ``TracedFedAvg``
    is the traced counterpart whose entire state — one JAX PRNG key per
    lane — is a *carried pytree*: ``init_state`` builds it host-side,
    ``step`` consumes and returns it inside the scan (split the key,
    take the first H of a random permutation of the N devices — the
    same uniform-without-replacement draw as ``FedAvgScheduler``, from
    the JAX stream instead of numpy's, so the two match in distribution
    but not bitwise). Stateful policies (IKC/VKC rotation sets) stay
    host-side: ``SweepRunner.run(fused=...)`` precomputes their (R, S,
    H) schedule tensor up front and feeds it to the scan as ``xs``,
    which is exact because scheduling never depends on training state.
    """
    n_devices: int
    H: int

    def __post_init__(self):
        if not 0 < self.H <= self.n_devices:
            raise ValueError(f"need 0 < H <= N, got H={self.H}, "
                             f"N={self.n_devices}")

    def init_state(self, seed: int):
        """Per-lane carried state: a PRNG key (host-side, once)."""
        import jax
        return jax.random.PRNGKey(seed)

    def step(self, state):
        """One traced scheduling round: (state) -> (new_state, sched).

        Pure jnp — callable under jit/vmap/scan. Splits the carried key
        and returns H distinct uniform device ids as int32.
        """
        import jax
        import jax.numpy as jnp
        key, sub = jax.random.split(state)
        sched = jax.random.permutation(sub, self.n_devices)[:self.H]
        return key, sched.astype(jnp.int32)
