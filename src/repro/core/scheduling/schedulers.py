"""Device scheduling policies: FedAvg-random, VKC (Alg. 3), IKC (Alg. 4).

All schedulers expose ``schedule(rng) -> np.ndarray[H]`` of device indices.
State (IKC's per-cluster rotation sets G_k) lives on the scheduler object,
exactly mirroring the paper's set-transfer semantics.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


class Scheduler:
    def schedule(self, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


class FedAvgScheduler(Scheduler):
    """[3]: uniformly random H devices per round."""

    def __init__(self, n_devices: int, H: int):
        self.n_devices = n_devices
        self.H = H

    def schedule(self, rng) -> np.ndarray:
        return rng.choice(self.n_devices, self.H, replace=False)


def _topup(selected: List[int], n_devices: int, target: int, rng) -> List[int]:
    """Alg.3 lines 12-15 / Alg.4 lines 21-24: random devices from the
    unscheduled pool until |H_i| == target."""
    if len(selected) < target:
        pool = np.setdiff1d(np.arange(n_devices), np.asarray(selected, int))
        extra = rng.choice(pool, target - len(selected), replace=False)
        selected = selected + list(extra)
    return selected


class VKCScheduler(Scheduler):
    """Algorithm 3 — vanilla K-Center: h random devices per cluster."""

    def __init__(self, clusters: Sequence[int], h: int):
        clusters = np.asarray(clusters)
        self.n_devices = len(clusters)
        self.K = int(clusters.max()) + 1
        self.h = h
        self.members = [np.flatnonzero(clusters == k) for k in range(self.K)]

    @property
    def H(self) -> int:
        return self.h * self.K

    def schedule(self, rng) -> np.ndarray:
        sel: List[int] = []
        for k in range(self.K):
            ck = self.members[k]
            if len(ck) >= self.h:                       # line 7
                sel += list(rng.choice(ck, self.h, replace=False))
            else:                                       # line 9
                sel += list(ck)
        sel = _topup(sel, self.n_devices, self.H, rng)
        return np.asarray(sel)


class IKCScheduler(Scheduler):
    """Algorithm 4 — improved K-Center with per-cluster rotation sets G_k.

    C_k = not-recently-scheduled members, G_k = recently scheduled. Fresh
    devices are preferred; when C_k runs dry it is refilled from G_k,
    guaranteeing every cluster member is scheduled before any repeats.
    """

    def __init__(self, clusters: Sequence[int], h: int):
        clusters = np.asarray(clusters)
        self.n_devices = len(clusters)
        self.K = int(clusters.max()) + 1
        self.h = h
        self.C = [list(np.flatnonzero(clusters == k)) for k in range(self.K)]
        self.G: List[List[int]] = [[] for _ in range(self.K)]

    @property
    def H(self) -> int:
        return self.h * self.K

    def schedule(self, rng) -> np.ndarray:
        sel: List[int] = []
        for k in range(self.K):
            Ck, Gk, h = self.C[k], self.G[k], self.h
            if len(Ck) + len(Gk) >= h:
                if len(Ck) >= h:                        # line 9
                    pick = list(rng.choice(Ck, h, replace=False))
                    self.C[k] = [d for d in Ck if d not in pick]
                    self.G[k] = Gk + pick
                else:                                   # lines 11-14
                    pick = list(Ck)
                    need = h - len(pick)
                    from_g = list(rng.choice(Gk, need, replace=False))
                    pick += from_g
                    remaining = [d for d in Gk if d not in from_g]
                    self.C[k] = remaining               # line 13
                    self.G[k] = list(pick)              # line 14
                sel += pick
            else:                                       # line 17
                sel += list(Ck) + list(Gk)
        sel = _topup(sel, self.n_devices, self.H, rng)
        return np.asarray(sel)
