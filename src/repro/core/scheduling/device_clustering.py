"""Algorithm 2 — K-means-based device clustering.

Every device trains the auxiliary model (global model w0 for VKC; the mini
model ξ on 1x10x10 crops for IKC) for L local iterations from a common
init, uploads the weights, and the cloud K-means-clusters the weight
vectors into K clusters.

``clustering_cost`` prices Algorithm 2 with the paper's cost model: every
device computes L iterations and uploads ``aux_bits`` once (uniform
bandwidth share of its nearest edge — clustering happens before
assignment, Alg. 2 line 3 assigns devices arbitrarily; we use nearest-edge).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.clustering import kmeans_best_of
from repro.core.local_train import cohort_local_sgd
from repro.utils import tree_flatten_to_vector


def auxiliary_weight_vectors(apply_fn: Callable, init_params, X, y, mask,
                             L: int, lr: float) -> jnp.ndarray:
    """Train the auxiliary model on every device; return (N, P) weights."""
    N = X.shape[0]
    params_per_dev = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (N,) + p.shape), init_params)
    trained = cohort_local_sgd(apply_fn, params_per_dev, X, y, mask, L, lr)
    flat = jax.vmap(tree_flatten_to_vector)(trained)
    return flat


def run_device_clustering(key, apply_fn: Callable, init_params, X, y, mask,
                          K: int, L: int, lr: float,
                          use_kernel: bool = False
                          ) -> Tuple[np.ndarray, jnp.ndarray]:
    """Algorithm 2. Returns (labels (N,), weight vectors (N, P))."""
    vecs = auxiliary_weight_vectors(apply_fn, init_params, X, y, mask, L, lr)
    # standardise features (weights have heterogeneous scales across layers)
    mu = jnp.mean(vecs, axis=0, keepdims=True)
    sd = jnp.std(vecs, axis=0, keepdims=True) + 1e-8
    labels, _ = kmeans_best_of(key, (vecs - mu) / sd, K, restarts=8,
                               use_kernel=use_kernel)
    return np.asarray(labels), vecs


@functools.partial(jax.jit, static_argnames=("sp",))
def _clustering_cost_core(sp: cm.SystemParams, u, D, p, f_max, g, B_m,
                          aux_bits, compute_scale):
    """Traceable Alg.-2 pricing: one compiled segment program (nearest-
    edge bincount via segment_sum + two reductions) instead of op-by-op
    eager dispatch — the difference between ms and s at N=10^5."""
    M = g.shape[1]
    nearest = jnp.argmax(g, axis=1)                           # (N,)
    counts = jax.ops.segment_sum(jnp.ones_like(nearest), nearest,
                                 num_segments=M)
    b = B_m[nearest] / jnp.maximum(counts[nearest], 1)
    g_near = jnp.max(g, axis=1)                               # g[n, nearest[n]]
    u_aux = u * compute_scale
    t_c = cm.t_cmp(sp, u_aux, D, f_max)                       # one round of L iters
    e_c = cm.e_cmp(sp, u_aux, D, f_max)
    t_x = cm.t_com(sp, b, g_near, p, model_bits=aux_bits)
    e_x = cm.e_com(sp, b, g_near, p, model_bits=aux_bits)
    return jnp.max(t_c + t_x), jnp.sum(e_c + e_x)


def clustering_cost(sp: cm.SystemParams, pop: cm.Population,
                    aux_bits: float,
                    compute_scale: float = 1.0) -> Tuple[float, float]:
    """(time delay, energy) of Algorithm 2 under the cost model.

    All N devices compute L iterations over their D_n samples at f_max and
    upload `aux_bits` once via the nearest edge, sharing its bandwidth
    uniformly among the devices that pick it.

    `compute_scale` scales the per-sample CPU cycles to the auxiliary
    model's size (u_n in Table I is defined for the task model; the mini
    model ξ costs ~1/70 of the CNN's FLOPs per sample — this is what makes
    the paper's Table II IKC delay 3.1 s vs 128 s, not just the upload).
    """
    delay, energy = _clustering_cost_core(
        sp, pop.u, pop.D, pop.p, pop.f_max, pop.g, pop.B_m,
        jnp.asarray(aux_bits, jnp.float32),
        jnp.asarray(compute_scale, jnp.float32))
    return float(delay), float(energy)
