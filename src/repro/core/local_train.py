"""Per-device local training (paper eq. (1)) — vmapped full-batch GD.

Device datasets are padded to a common ``Dmax`` with a validity mask so the
whole scheduled cohort trains as one vmapped, jitted computation.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp



def masked_loss(apply_fn: Callable, params, X, y, mask) -> jnp.ndarray:
    """Mean CE over valid samples only. X: (Dmax, ...), mask: (Dmax,)."""
    logits = apply_fn(params, X)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    per = (lse - gold) * mask
    return jnp.sum(per) / jnp.maximum(jnp.sum(mask), 1.0)


def local_sgd(apply_fn: Callable, params, X, y, mask, L: int, lr: float):
    """L full-batch GD steps (eq. (1)) on one device."""
    grad_fn = jax.grad(masked_loss, argnums=1)

    def body(p, _):
        g = grad_fn(apply_fn, p, X, y, mask)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(body, params, None, length=L)
    return params


def cohort_local_sgd(apply_fn: Callable, params_per_dev, X, y, mask,
                     L: int, lr: float):
    """vmap of local_sgd over the device axis.

    params_per_dev: pytree with leading device axis; X: (H, Dmax, ...).
    """
    def fn(p, xx, yy, mm):
        return local_sgd(apply_fn, p, xx, yy, mm, L, lr)
    return jax.vmap(fn)(params_per_dev, X, y, mask)
