"""Resource allocation within a single edge server — problem (27).

minimise   E_m + λ T_m
           = Q Σ_n [ α/2 L f_n² u_n D_n + p_n z/η_n(b_n) ]  + E_cloud
           + λ ( Q max_n [ L u_n D_n / f_n + z/η_n(b_n) ] + T_cloud )
s.t.       Σ b_n <= B_m,   0 <= f_n <= f_max.

The objective is jointly convex (paper §V-D). CVXPY is not available in
this container, so we solve it natively in JAX:

  * reparameterise onto the feasible set — bandwidth via a masked softmax
    scaled by B_m (the optimum uses the full budget: both T and E strictly
    decrease in b_n), frequency via a box sigmoid;
  * smooth the max with a temperature-annealed log-sum-exp and run Adam;
  * report the *hard*-max objective of the final iterate.

``allocate`` is jit-compiled with a fixed device-slot count and a validity
mask, so HFEL's search and the D3QN reward loop can call it thousands of
times cheaply. ``allocate_batch`` vmaps the same solver over a leading
edge axis, and ``allocate_all_edges`` gathers a population + schedule into
the ``(M, n_slots)`` batch so all M per-edge problems are solved in ONE
jit call — the building block of the fused round engine
(``repro.core.framework.round_step`` and ``repro.core.sweep``).
``flatten_trials``/``unflatten_trials`` map trial-major ``(K, E, ...)``
candidate batches onto the same flat layout, which is how the batched
HFEL search solves the affected edges of K moves per dispatch.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core.cost_model import SystemParams


class AllocResult(NamedTuple):
    b: jnp.ndarray        # (n_slots,) bandwidth [Hz]
    f: jnp.ndarray        # (n_slots,) CPU frequency [Hz]
    T_edge: jnp.ndarray   # scalar: Q max_n (T_cmp + T_com)
    E_edge: jnp.ndarray   # scalar: Q sum_n (E_cmp + E_com)
    obj: jnp.ndarray      # E_edge + lam * T_edge   (cloud terms excluded)


def _edge_terms(sp: SystemParams, u, D, p, g, b, f, mask):
    t = cm.t_cmp(sp, u, D, f) + cm.t_com(sp, b, g, p)
    e = cm.e_cmp(sp, u, D, f) + cm.e_com(sp, b, g, p)
    t = jnp.where(mask, t, 0.0)
    e = jnp.where(mask, e, 0.0)
    return t, e


def _allocate_core(sp: SystemParams, u, D, p, g, B_m, mask,
                   steps: int, theta0=None):
    """Solve (27) for one edge. All inputs (n_slots,) + scalar B_m.

    mask: bool (n_slots,) — which slots hold real devices.
    theta0: optional (tb, tf) reparameterised warm start — e.g. the
    incumbent solution of a nearby problem (one device joined/left the
    edge), which lets callers converge in far fewer Adam steps than the
    cold init. None keeps the historical cold start.

    Returns (AllocResult, theta) where theta is the final (tb, tf) pair
    so callers can chain warm starts. Pure traceable body (no jit) so it
    can be vmapped over an edge axis or inlined into larger fused
    programs.
    """
    n = u.shape[0]
    any_dev = jnp.any(mask)
    neg = -1e9

    def unpack(theta):
        tb, tf = theta
        logits = jnp.where(mask, tb, neg)
        b = B_m * jax.nn.softmax(logits)
        f = sp.f_max * jax.nn.sigmoid(tf)
        f = jnp.maximum(f, 1e6)
        return b, f

    def smooth_obj(theta, tau):
        b, f = unpack(theta)
        t, e = _edge_terms(sp, u, D, p, g, b, f, mask)
        # finite floor, NOT -inf: grad(logsumexp) with -inf entries is NaN
        # (poisoned every masked allocation -> HFEL silently no-opped;
        # see EXPERIMENTS.md §Perf correctness notes)
        tmask = jnp.where(mask, t / tau, -1e30)
        tmax = tau * jax.scipy.special.logsumexp(tmask)
        return sp.Q * jnp.sum(e) + sp.lam * sp.Q * tmax

    if theta0 is None:
        theta0 = (jnp.zeros(n), jnp.full((n,), 1.0))  # f starts ~0.73 f_max

    # Adam
    lr, b1, b2, eps = 0.08, 0.9, 0.999, 1e-8
    grad_fn = jax.grad(smooth_obj)

    def body(i, carry):
        theta, m, v = carry
        # anneal the softmax temperature from loose to tight
        t_hard = _hard_T(theta)
        tau = jnp.maximum(1e-6, t_hard * (0.2 * (1.0 - i / steps) + 0.01))
        gr = grad_fn(theta, tau)
        m = jax.tree.map(lambda a, g_: b1 * a + (1 - b1) * g_, m, gr)
        v = jax.tree.map(lambda a, g_: b2 * a + (1 - b2) * g_ * g_, v, gr)
        t_ = (i + 1).astype(jnp.float32)
        mhat = jax.tree.map(lambda a: a / (1 - b1 ** t_), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2 ** t_), v)
        theta = jax.tree.map(lambda th, mh, vh: th - lr * mh / (jnp.sqrt(vh) + eps),
                             theta, mhat, vhat)
        return theta, m, v

    def _hard_T(theta):
        b, f = unpack(theta)
        t, _ = _edge_terms(sp, u, D, p, g, b, f, mask)
        return jnp.max(jnp.where(mask, t, 0.0)) + 1e-12

    zeros = jax.tree.map(jnp.zeros_like, theta0)
    theta, _, _ = jax.lax.fori_loop(
        0, steps, body, (theta0, zeros, zeros))

    b, f = unpack(theta)
    t, e = _edge_terms(sp, u, D, p, g, b, f, mask)
    T_edge = sp.Q * jnp.max(jnp.where(mask, t, 0.0))
    E_edge = sp.Q * jnp.sum(e)
    obj = jnp.where(any_dev, E_edge + sp.lam * T_edge, 0.0)
    res = AllocResult(b, f, jnp.where(any_dev, T_edge, 0.0),
                      jnp.where(any_dev, E_edge, 0.0), obj)
    return res, theta


def _allocate_impl(sp: SystemParams, u, D, p, g, B_m, mask,
                   steps: int) -> AllocResult:
    """Cold-start solve of (27); see ``_allocate_core``."""
    return _allocate_core(sp, u, D, p, g, B_m, mask, steps)[0]


@functools.partial(jax.jit, static_argnames=("sp", "steps"))
def allocate(sp: SystemParams, u, D, p, g, B_m, mask,
             steps: int = 300) -> AllocResult:
    """Single-edge solve of (27); see ``_allocate_impl``."""
    return _allocate_impl(sp, u, D, p, g, B_m, mask, steps)


@functools.partial(jax.jit, static_argnames=("sp", "steps"))
def allocate_batch(sp: SystemParams, u, D, p, g, B_m, mask,
                   steps: int = 300) -> AllocResult:
    """Solve (27) for a batch of edges in one call.

    u, D, p, g, mask: (M, n_slots); B_m: (M,). Returns an AllocResult
    whose fields carry the leading edge axis: b, f (M, n_slots);
    T_edge, E_edge, obj (M,).
    """
    return jax.vmap(
        lambda u_, D_, p_, g_, B_, m_:
            _allocate_impl(sp, u_, D_, p_, g_, B_, m_, steps)
    )(u, D, p, g, B_m, mask)


@functools.partial(jax.jit, static_argnames=("sp", "steps"))
def allocate_batch_warm(sp: SystemParams, u, D, p, g, B_m, mask, tb0, tf0,
                        steps: int = 60):
    """``allocate_batch`` warm-started from caller-provided solver state.

    tb0, tf0: (M, n_slots) reparameterised (bandwidth-logit, frequency)
    iterates from a prior solve of a *nearby* problem — e.g. HFEL's
    incumbent per-edge solutions, where a trial edge differs by one
    joined/left device. Starting at the incumbent lets ``steps`` be a
    fraction of the cold-start count at equal solution quality, which is
    what makes K-candidate search rounds cheaper than K serial trials
    in FLOPs and not just in dispatch overhead.

    Returns (AllocResult, (tb, tf)) with the leading edge axis on every
    field, the final iterates ready to seed the next warm solve.
    """
    return jax.vmap(
        lambda u_, D_, p_, g_, B_, m_, tb_, tf_:
            _allocate_core(sp, u_, D_, p_, g_, B_, m_, steps, (tb_, tf_))
    )(u, D, p, g, B_m, mask, tb0, tf0)


def flatten_trials(u, D, p, g, B_m, mask, *extras):
    """Collapse trial-major allocation inputs to ``allocate_batch``'s layout.

    The batched HFEL search evaluates K candidate moves per round, each
    re-solving its E affected edges (E = 2 for transfer/exchange moves).
    Inputs arrive trial-major — u, D, p, g, mask ``(K, E, n_slots)`` and
    B_m ``(K, E)`` — and are reshaped to the flat ``(K*E, ...)`` batch
    that ``allocate_batch`` consumes, so all K·E edge problems solve in
    ONE jit call. Row ``k*E + e`` holds trial k's e-th affected edge;
    ``unflatten_trials`` is the inverse. Any ``extras`` (e.g. the
    ``(K, E, n_slots)`` warm-start iterates for ``allocate_batch_warm``)
    are flattened the same way and appended to the returned tuple.
    """
    K, E = mask.shape[:2]

    def flat(a):
        a = jnp.asarray(a)
        return a.reshape((K * E,) + a.shape[2:])

    return (flat(u), flat(D), flat(p), flat(g), flat(B_m), flat(mask),
            *(flat(x) for x in extras))


def unflatten_trials(res: AllocResult, n_trials: int, n_edges: int
                     ) -> AllocResult:
    """Reshape a flat ``(n_trials*n_edges, ...)`` AllocResult back to
    trial-major ``(n_trials, n_edges, ...)`` — the inverse of
    ``flatten_trials`` on every result field."""
    return AllocResult(*(jnp.reshape(a, (n_trials, n_edges) + a.shape[1:])
                         for a in res))


def gather_edge_inputs(pop, sched, assign):
    """Gather the (M, H) per-edge allocation inputs for a scheduled cohort.

    sched: (H,) device indices; assign: (H,) edge id per scheduled device.
    Returns (u, D, p, g, B_m, mask) ready for ``allocate_batch``: device
    features broadcast across the edge axis, per-edge gains transposed to
    (M, H), and mask[m, h] = (assign[h] == m).
    """
    sched = jnp.asarray(sched)
    assign = jnp.asarray(assign)
    M = pop.n_edges
    H = sched.shape[0]
    u = jnp.broadcast_to(pop.u[sched], (M, H))
    D = jnp.broadcast_to(pop.D[sched], (M, H))
    p = jnp.broadcast_to(pop.p[sched], (M, H))
    g = pop.g[sched].T                                  # (M, H)
    mask = assign[None, :] == jnp.arange(M)[:, None]    # (M, H)
    return u, D, p, g, pop.B_m, mask


def allocate_all_edges(sp: SystemParams, pop, sched, assign,
                       steps: int = 300) -> AllocResult:
    """Solve (27) for every edge of a population in ONE jit call.

    Replaces the per-edge Python loop (M separate ``allocate`` dispatches
    with host round-trips) with a single vmapped solve. Returns the
    batched AllocResult of ``allocate_batch``.
    """
    u, D, p, g, B_m, mask = gather_edge_inputs(pop, sched, assign)
    return allocate_batch(sp, u, D, p, g, B_m, mask, steps=steps)


def select_device_allocation(res: AllocResult, assign):
    """Scatter a batched AllocResult back to per-device (H,) b and f:
    device h reads row assign[h] of the (M, H) allocation."""
    assign = jnp.asarray(assign)
    h_idx = jnp.arange(assign.shape[0])
    return res.b[assign, h_idx], res.f[assign, h_idx]


def allocate_uniform(sp: SystemParams, u, D, p, g, B_m, mask) -> AllocResult:
    """Baseline: equal bandwidth split, f = f_max."""
    n_act = jnp.maximum(jnp.sum(mask), 1)
    b = jnp.where(mask, B_m / n_act, 1.0)
    f = jnp.full_like(u, sp.f_max)
    t, e = _edge_terms(sp, u, D, p, g, b, f, mask)
    T_edge = sp.Q * jnp.max(jnp.where(mask, t, 0.0))
    E_edge = sp.Q * jnp.sum(e)
    return AllocResult(b, f, T_edge, E_edge, E_edge + sp.lam * T_edge)


def edge_objective_with_cloud(sp: SystemParams, res: AllocResult,
                              g_cloud_m) -> jnp.ndarray:
    """E_m + λ T_m including the constant cloud-uplink terms (13),(14)."""
    T_cl, E_cl = cm.cloud_cost(sp, g_cloud_m)
    return (res.E_edge + E_cl) + sp.lam * (res.T_edge + T_cl)
