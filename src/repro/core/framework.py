"""Algorithm 6 — the full proposed HFL framework.

Per global iteration i:
  1. schedule H devices (IKC / VKC / FedAvg),
  2. assign them to edges (D3QN / HFEL / geographic),
  3. per-edge convex resource allocation (bandwidth + CPU frequency),
  4. HFL training (Algorithm 1) on the scheduled cohort,
  5. evaluate; stop when the target accuracy is reached.

Steps 3+4 plus the cost bookkeeping (13)/(14) run through the fused
``round_step`` engine: assignment one-hot construction, the vmapped
all-edges resource allocation, ``round_cost`` and the Algorithm-1
training are one jitted program, so a round costs ONE device dispatch +
host sync instead of ~M+3 (the old per-edge Python loop is kept as
``engine="sequential"`` — the parity oracle for tests).
``FrameworkConfig(agg_kernel=True)`` additionally routes the Algorithm-1
edge/cloud aggregation through the fused masked-weight
``kernels/hier_agg`` Pallas kernel (interpret mode off-TPU).

Tracks the paper's reported quantities: accuracy trajectory, T (13),
E (14), objective E + λT (15), and transmitted message volume per round
and cumulative (Fig. 7f/7g), plus the one-off clustering cost (Table II).

The trained payload is pluggable: ``FrameworkConfig.arch`` resolves a
:class:`repro.models.spec.ModelSpec` through ``configs.registry`` —
the default ``"hfl-cnn"`` is the paper's CNN (bitwise-identical to the
pre-spec engines), any other registry id trains that arch's smoke-config
variant as a sequence classifier (see ``docs/engine.md``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.core import resource as ra
from repro.core.clustering import adjusted_rand_index
from repro.core.hfl import (hfl_global_iteration, hfl_global_iteration_core,
                            pad_device_data)
from repro.core.scheduling import (FedAvgScheduler, IKCScheduler,
                                   VKCScheduler, run_device_clustering)
from repro.core.scheduling.device_clustering import clustering_cost
from repro.configs.registry import get_hfl_spec
from repro.data.partition import FederatedData
from repro.utils import tree_bytes


def round_step_core(apply_fn, sp: cm.SystemParams, params, u, D, p, g,
                    g_cloud, B_m, X, y, mask, sizes, assign, lr, *,
                    M: int, L: int, Q: int, alloc_steps: int,
                    agg_kernel: bool = False, codec=None,
                    codec_state=None, codec_key=None):
    """Traceable fused round: one global iteration minus scheduling.

    Inputs are pre-gathered for the scheduled cohort: u/D/p/sizes (H,),
    g (H, M) gains to every edge, X/y/mask (H, Dmax, ...), assign (H,).
    Fuses (a) per-edge one-hot/mask construction, (b) the vmapped
    all-edges resource allocation (27), (c) round costs (13)/(14) and
    (d) Algorithm-1 training into one program. ``agg_kernel=True`` runs
    the hierarchical aggregation (2)-(3) through the fused masked-weight
    ``kernels/hier_agg`` Pallas kernel (interpret off-TPU) instead of
    masked XLA einsums. Returns (new_params, (T_i, E_i, T_m, E_m, b, f)).

    Compression: with an active ``codec`` (static
    ``CompressionConfig``), pass the caller's ``sp`` already patched
    with the codec's per-message bits (``compression.message_bits``) so
    the allocation and eqs. (7)-(12) price the compressed payload;
    ``codec_state`` is ``(dev_resid, edge_resid)`` error-feedback trees
    for the cohort (H, ...) and the edges (M, ...). The return then
    becomes ``(new_params, (new_dev_resid, new_edge_resid), aux)``.
    """
    H = assign.shape[0]
    edge_mask = assign[None, :] == jnp.arange(M)[:, None]       # (M, H)
    res = ra.allocate_batch(
        sp,
        jnp.broadcast_to(u, (M, H)), jnp.broadcast_to(D, (M, H)),
        jnp.broadcast_to(p, (M, H)), g.T, B_m, edge_mask,
        steps=alloc_steps)
    b, f = ra.select_device_allocation(res, assign)             # (H,) each
    g_sel = g[jnp.arange(H), assign]
    T_i, E_i, T_m, E_m = cm.round_cost_gathered(
        sp, u, D, p, g_sel, g_cloud, assign, b, f, M)
    if codec is not None and codec.active:
        dev_resid, edge_resid = codec_state
        new_params, dev_resid, edge_resid = hfl_global_iteration_core(
            apply_fn, params, X, y, mask, sizes, assign, M=M, L=L, Q=Q,
            lr=lr, agg_kernel=agg_kernel, codec=codec,
            dev_resid=dev_resid, edge_resid=edge_resid,
            codec_key=codec_key)
        return new_params, (dev_resid, edge_resid), (T_i, E_i, T_m, E_m,
                                                     b, f)
    new_params = hfl_global_iteration_core(
        apply_fn, params, X, y, mask, sizes, assign, M=M, L=L, Q=Q, lr=lr,
        agg_kernel=agg_kernel)
    return new_params, (T_i, E_i, T_m, E_m, b, f)


@functools.partial(jax.jit, static_argnames=(
    "apply_fn", "sp", "M", "L", "Q", "alloc_steps", "agg_kernel", "codec"))
def round_step(apply_fn, sp: cm.SystemParams, params, u, D, p, g, g_cloud,
               B_m, X, y, mask, sizes, assign, lr, *, M: int, L: int,
               Q: int, alloc_steps: int, agg_kernel: bool = False,
               codec=None, codec_state=None, codec_key=None):
    """Jitted fused round — see ``round_step_core``."""
    return round_step_core(apply_fn, sp, params, u, D, p, g, g_cloud, B_m,
                           X, y, mask, sizes, assign, lr,
                           M=M, L=L, Q=Q, alloc_steps=alloc_steps,
                           agg_kernel=agg_kernel, codec=codec,
                           codec_state=codec_state, codec_key=codec_key)


@dataclasses.dataclass
class FrameworkConfig:
    arch: str = "hfl-cnn"           # model payload (configs.registry id)
    scheduler: str = "ikc"          # ikc | vkc | fedavg
    assigner: str = "geo"           # drl | hfel | geo
    H: int = 50
    K: int = 10
    lr: float = 0.01
    target_acc: float = 0.875
    max_iters: int = 100
    alloc_steps: int = 200
    seed: int = 0
    use_kernel: bool = False        # Pallas kmeans kernel (interpret on CPU)
    agg_kernel: bool = False        # Pallas hier_agg aggregation backend
    engine: str = "fused"           # fused | sequential (per-edge oracle)
    hfel_search: str = "batched"    # batched | serial (assigner="hfel")
    hfel_candidates: int = 16       # K moves per batched HFEL round
    compression: comp.CompressionConfig = dataclasses.field(
        default_factory=comp.CompressionConfig)   # uplink update codec


class HFLFramework:
    def __init__(self, sp: cm.SystemParams, pop: cm.Population,
                 fed: FederatedData, cfg: FrameworkConfig,
                 drl_params: Optional[dict] = None):
        self.sp, self.pop, self.fed, self.cfg = sp, pop, fed, cfg
        self.rng = np.random.default_rng(cfg.seed)
        key = jax.random.PRNGKey(cfg.seed)
        k_model, k_mini, k_cluster = jax.random.split(key, 3)

        # payload resolution: cfg.arch -> ModelSpec. The default
        # "hfl-cnn" reproduces the paper CNN construction bit for bit
        # (same key-split order, same cnn_apply object -> same jit
        # cache entries as the pre-spec engines).
        self.spec = get_hfl_spec(cfg.arch)
        self.model_params = self.spec.init_fn(k_model, fed)
        self.apply_fn = self.spec.apply_fn
        self.model_bits = tree_bytes(self.model_params) * 8
        self.sp = dataclasses.replace(self.sp, model_bits=float(self.model_bits))

        # uplink codec: compressed per-message bits price every uplink
        # (device->edge and edge->cloud ship the same codec), so the
        # round sp the allocator/cost model see carries them; identity
        # codec => uplink_bits == model_bits and sp_round == sp (the
        # same jit cache entry — bitwise parity with the seed path).
        self.codec = cfg.compression
        if self.codec.active and cfg.engine == "sequential":
            raise ValueError("compression requires engine='fused' (the "
                             "sequential oracle ships raw payloads)")
        self.uplink_bits = comp.message_bits(self.codec, self.model_params)
        self.sp_round = dataclasses.replace(
            self.sp, model_bits=float(self.uplink_bits))
        self.codec_state = None
        if self.codec.active:
            self.codec_state = (
                comp.init_state(self.codec, self.model_params,
                                fed.n_devices),
                comp.init_state(self.codec, self.model_params,
                                pop.n_edges))

        self.X, self.y, self.mask = pad_device_data(fed)
        self.clustering_stats: Dict = {}
        self._setup_scheduler(k_mini, k_cluster)
        self._setup_assigner(drl_params)
        self.history: List[Dict] = []

    # ------------------------------------------------------------ setup

    def _setup_scheduler(self, k_mini, k_cluster):
        # mirrored by core/sweep.py build_scheduler (standalone, different
        # key derivation, no cost/ARI bookkeeping) — keep the clustering
        # recipe in sync with it
        cfg, fed = self.cfg, self.fed
        h = max(1, cfg.H // cfg.K)
        if cfg.scheduler == "fedavg":
            self.scheduler = FedAvgScheduler(fed.n_devices, cfg.H)
            return
        if cfg.scheduler == "ikc":
            # auxiliary mini model ξ on the spec's clustering crop
            # (images: 1x10x10 random crops; sequences: token crops)
            mini_params = self.spec.mini_init_fn(k_mini, fed)
            compute_scale = (tree_bytes(mini_params)
                             / max(1, tree_bytes(self.model_params)))
            crop = self.spec.mini_preprocess_fn(self.X, k_mini)
            aux_bits = tree_bytes(mini_params) * 8
            labels, _ = run_device_clustering(
                k_cluster, self.spec.mini_apply_fn, mini_params, crop,
                self.y, self.mask, cfg.K, self.sp.L, cfg.lr,
                use_kernel=cfg.use_kernel)
            self.scheduler = IKCScheduler(labels, h)
        else:  # vkc: heavyweight global model as auxiliary model
            aux_bits = self.model_bits
            labels, _ = run_device_clustering(
                k_cluster, self.apply_fn, self.model_params, self.X, self.y,
                self.mask, cfg.K, self.sp.L, cfg.lr,
                use_kernel=cfg.use_kernel)
            self.scheduler = VKCScheduler(labels, h)
            compute_scale = 1.0
        delay, energy = clustering_cost(self.sp, self.pop, aux_bits,
                                        compute_scale=compute_scale)
        self.clustering_stats = {
            "ari": adjusted_rand_index(labels, self.fed.majority_class),
            "delay_s": delay, "energy_j": energy,
            "aux_bits": float(aux_bits)}

    def _setup_assigner(self, drl_params):
        from repro.core.assignment import (DRLAssigner, GeoAssigner,
                                           HFELAssigner)
        a = self.cfg.assigner
        if a == "drl":
            assert drl_params is not None, "need trained D3QN params"
            self.assigner = DRLAssigner(self.sp, drl_params)
        elif a == "hfel":
            self.assigner = HFELAssigner(
                self.sp, search=self.cfg.hfel_search,
                n_candidates=self.cfg.hfel_candidates)
        else:
            self.assigner = GeoAssigner(self.sp)

    # ------------------------------------------------------------- round

    def run_round(self, i: int) -> Dict:
        sp, pop = self.sp, self.pop
        sched = np.asarray(self.scheduler.schedule(self.rng))
        t0 = time.perf_counter()
        assign, _ = self.assigner.assign(pop, sched, self.rng)
        assign = np.asarray(assign)
        assign_latency = time.perf_counter() - t0
        H = len(sched)

        if self.cfg.engine == "sequential":
            T_i, E_i = self._sequential_alloc_cost_train(sched, assign)
        elif self.codec.active:
            dev_resid, edge_resid = self.codec_state
            cohort_resid = jax.tree.map(lambda r: r[sched], dev_resid)
            (self.model_params, (cohort_resid, edge_resid),
             (T_i, E_i, _, _, _, _)) = round_step(
                self.apply_fn, self.sp_round, self.model_params,
                pop.u[sched], pop.D[sched], pop.p[sched], pop.g[sched],
                pop.g_cloud, pop.B_m,
                self.X[sched], self.y[sched], self.mask[sched],
                pop.D[sched], jnp.asarray(assign), self.cfg.lr,
                M=pop.n_edges, L=sp.L, Q=sp.Q,
                alloc_steps=self.cfg.alloc_steps,
                agg_kernel=self.cfg.agg_kernel, codec=self.codec,
                codec_state=(cohort_resid, edge_resid),
                codec_key=comp.round_key(self.codec, self.cfg.seed, i))
            self.codec_state = (
                jax.tree.map(lambda full, nr: full.at[sched].set(nr),
                             dev_resid, cohort_resid),
                edge_resid)
        else:
            self.model_params, (T_i, E_i, _, _, _, _) = round_step(
                self.apply_fn, sp, self.model_params,
                pop.u[sched], pop.D[sched], pop.p[sched], pop.g[sched],
                pop.g_cloud, pop.B_m,
                self.X[sched], self.y[sched], self.mask[sched],
                pop.D[sched], jnp.asarray(assign), self.cfg.lr,
                M=pop.n_edges, L=sp.L, Q=sp.Q,
                alloc_steps=self.cfg.alloc_steps,
                agg_kernel=self.cfg.agg_kernel)

        acc = self.spec.eval_fn(self.model_params,
                                self.fed.X_test, self.fed.y_test)
        msg_bits = cm.round_msg_bits(self.sp, sp.Q * H, pop.n_edges,
                                     msg_bits=self.uplink_bits)
        rec = {"iter": i, "acc": acc, "T_i": float(T_i), "E_i": float(E_i),
               "obj_i": float(E_i + sp.lam * T_i),
               "msg_bits": float(msg_bits),
               "uplink_bytes": float(sp.Q * H * self.uplink_bits / 8),
               "codec": self.codec.codec,
               "assign_latency_s": assign_latency,
               "H": H}
        self.history.append(rec)
        return rec

    def _sequential_alloc_cost_train(self, sched, assign):
        """Pre-engine per-edge path: M separate allocate dispatches with
        host round-trips, then round_cost + Algorithm 1. Kept verbatim as
        the parity oracle for the fused engine."""
        sp, pop = self.sp, self.pop
        H = len(sched)
        b = np.zeros(H)
        f = np.zeros(H)
        for m in range(pop.n_edges):
            mask = jnp.asarray(assign == m)
            res = ra.allocate(sp, pop.u[sched], pop.D[sched], pop.p[sched],
                              pop.g[sched, m], pop.B_m[m], mask,
                              steps=self.cfg.alloc_steps)
            sel = np.asarray(assign == m)
            b[sel] = np.asarray(res.b)[sel]
            f[sel] = np.asarray(res.f)[sel]

        T_i, E_i, _, _ = cm.round_cost(
            sp, pop, jnp.asarray(sched), jnp.asarray(assign),
            jnp.asarray(b), jnp.asarray(f))

        # Algorithm 1
        self.model_params = hfl_global_iteration(
            self.apply_fn, self.model_params,
            self.X[sched], self.y[sched], self.mask[sched],
            self.pop.D[sched], jnp.asarray(assign),
            M=pop.n_edges, L=sp.L, Q=sp.Q, lr=self.cfg.lr)
        return T_i, E_i

    def run(self, verbose: bool = True) -> Dict:
        for i in range(1, self.cfg.max_iters + 1):
            rec = self.run_round(i)
            if verbose:
                print(f"  [{self.cfg.scheduler}/{self.cfg.assigner}] "
                      f"iter {i:3d} acc={rec['acc']:.3f} "
                      f"T_i={rec['T_i']:.1f}s E_i={rec['E_i']:.1f}J")
            if rec["acc"] >= self.cfg.target_acc:
                break
        return self.summary()

    def summary(self) -> Dict:
        T = sum(r["T_i"] for r in self.history)
        E = sum(r["E_i"] for r in self.history)
        return {
            "iters": len(self.history),
            "final_acc": self.history[-1]["acc"] if self.history else 0.0,
            "T": T, "E": E, "objective": E + self.sp.lam * T,
            "total_msg_bits": sum(r["msg_bits"] for r in self.history),
            "msg_bits_per_round": (self.history[-1]["msg_bits"]
                                   if self.history else 0.0),
            "clustering": self.clustering_stats,
            "history": self.history,
        }
