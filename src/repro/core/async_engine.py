"""Event-driven asynchronous HFL engine — arrivals, dropouts, stragglers.

Every engine up to PR 7 is synchronous-round: the whole scheduled cohort
trains in lockstep and the round "takes" ``max`` of the member latencies.
Real IoT fleets are intermittent — devices join mid-round, drop out with
work in flight, and stragglers inflate the critical path. This module
runs one HFL global iteration as a *discrete-event simulation* on a
virtual clock:

* Scheduling, assignment and the convex resource allocation (27) are
  identical to the fused round engine — ``_alloc_and_price`` reuses the
  exact ``allocate_batch``/``select_device_allocation`` pattern of
  ``framework.round_step_core`` and prices each device's task with the
  per-device eq. (4)-(8) time/energy instead of the per-round reduction.
* Each dispatched device runs its L local GD steps (Algorithm 1 inner
  loop) and *returns the update at a trace-determined virtual time*:
  ``(t_cmp + t_com) * latency_scale`` (straggler inflation, optional
  log-normal jitter), driven by an :class:`~repro.core.cost_model.
  AvailabilityTrace` of arrival/dropout flips.
* Edge servers aggregate from FedBuff-style staleness-weighted buffers:
  a delivered update that trained against edge version ``v`` is merged
  at version ``V`` with weight ``D_n / (1 + (V - v))**a`` (eq. (2)
  generalised); the data mass of cohort members with nothing in the
  buffer anchors on the current edge model. After Q buffer flushes the
  edge uploads to the cloud; the cloud aggregates with the eq.-(3)
  cohort-data-size weights.
* Device state (dispatched / delivered / aborted) rides the same
  masked-lane machinery as the PR-4/5 done-masks: one fixed-shape
  ``(H, ...)`` cohort pytree, updated under boolean masks so every jit
  re-use hits the same compiled program.

Parity: with the degenerate trace (``AvailabilityTrace.always_on``,
unit latency scale, no jitter, wait-for-all buffers) the event loop
reproduces the synchronous ``round_step`` — same b/f allocations and
per-task costs bitwise, totals to float-accumulation-order tolerance,
same model params to ulp — pinned in ``tests/test_async_engine.py``
and documented as the oracle recipe in ``docs/async.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.core import resource as ra
from repro.configs.registry import get_hfl_spec
from repro.core.hfl import evaluate_in_batches, pad_device_data
from repro.core.local_train import cohort_local_sgd
from repro.data.partition import FederatedData
from repro.utils import tree_bytes


# ------------------------------------------------------ jitted helpers

@functools.partial(jax.jit, static_argnames=("sp", "M", "alloc_steps"))
def _alloc_and_price(sp, u, D, p, g, g_cloud, B_m, assign, *, M: int,
                     alloc_steps: int):
    """Cohort allocation + per-task pricing, one dispatch.

    The same all-edges ``allocate_batch`` / ``select_device_allocation``
    pattern as ``framework.round_step_core``, but returning the
    *per-device* task time/energy ``tc``/``ec`` (H,) so the event loop
    can spend them task by task, plus the per-edge cloud-hop costs.
    """
    H = assign.shape[0]
    edge_mask = assign[None, :] == jnp.arange(M)[:, None]       # (M, H)
    res = ra.allocate_batch(
        sp,
        jnp.broadcast_to(u, (M, H)), jnp.broadcast_to(D, (M, H)),
        jnp.broadcast_to(p, (M, H)), g.T, B_m, edge_mask,
        steps=alloc_steps)
    b, f = ra.select_device_allocation(res, assign)             # (H,) each
    g_sel = g[jnp.arange(H), assign]
    tc = cm.t_cmp(sp, u, D, f) + cm.t_com(sp, b, g_sel, p)
    ec = cm.e_cmp(sp, u, D, f) + cm.e_com(sp, b, g_sel, p)
    T_cl, E_cl = cm.cloud_cost(sp, g_cloud)                     # (M,) each
    return b, f, tc, ec, T_cl, E_cl


@functools.partial(jax.jit, static_argnames=("apply_fn", "L"))
def _train_dispatched(apply_fn, cohort_params, edge_params, assign,
                      dispatch_mask, X, y, mask, lr, *, L: int):
    """Pull edge models and run L local GD steps on the dispatched lanes.

    Fixed-shape masked update (PR-4/5 done-mask style): every lane runs
    through ``cohort_local_sgd``, but only lanes where ``dispatch_mask``
    is set start from their edge's current model and keep the trained
    result — so one compiled program serves every dispatch pattern.
    """
    def bmask(leaf):
        return dispatch_mask.reshape((-1,) + (1,) * (leaf.ndim - 1))

    pulled = jax.tree.map(lambda e: jnp.take(e, assign, axis=0),
                          edge_params)
    src = jax.tree.map(lambda c, q: jnp.where(bmask(c), q, c),
                       cohort_params, pulled)
    trained = cohort_local_sgd(apply_fn, src, X, y, mask, L, lr)
    return jax.tree.map(lambda c, t: jnp.where(bmask(c), t, c),
                        cohort_params, trained)


@functools.partial(jax.jit, static_argnames=("apply_fn", "L", "codec"))
def _train_dispatched_compressed(apply_fn, cohort_params, edge_params,
                                 assign, dispatch_mask, X, y, mask, lr,
                                 resid, key, *, L: int, codec):
    """``_train_dispatched`` with the uplink codec applied.

    Dispatched lanes train from their edge model, then ship
    ``encode(trained - pulled + resid)``; the buffered value is the
    edge-side reconstruction ``pulled + decode(...)`` (the staleness-
    weighted flush is linear in the decoded update, so merging the
    reconstruction is exactly merging the wire-format update).
    ``resid``: (H, ...) error-feedback rows for the scheduled cohort —
    updated only on dispatched lanes, like the params.
    """
    def bmask(leaf):
        return dispatch_mask.reshape((-1,) + (1,) * (leaf.ndim - 1))

    pulled = jax.tree.map(lambda e: jnp.take(e, assign, axis=0),
                          edge_params)
    src = jax.tree.map(lambda c, q: jnp.where(bmask(c), q, c),
                       cohort_params, pulled)
    trained = cohort_local_sgd(apply_fn, src, X, y, mask, L, lr)
    delta = jax.tree.map(lambda t, q: (t - q).astype(jnp.float32),
                         trained, pulled)
    dec, new_resid = comp.encode_decode(codec, key, delta, resid)
    recon = jax.tree.map(lambda q, d: (q + d).astype(q.dtype), pulled, dec)
    new_cohort = jax.tree.map(lambda c, t: jnp.where(bmask(c), t, c),
                              cohort_params, recon)
    new_resid = jax.tree.map(lambda r, nr: jnp.where(bmask(r), nr, r),
                             resid, new_resid)
    return new_cohort, new_resid


@jax.jit
def _flush_edge(edge_params, cohort_params, m, deliver_mask, member_mask,
                sizes, staleness, a):
    """Staleness-weighted buffer flush for edge ``m`` (eq. (2) general).

    Delivered members contribute with weight ``D_n / (1+staleness_n)**a``;
    the data mass of cohort members with nothing in the buffer anchors on
    the current edge model, so a flush with a partial buffer moves the
    edge model proportionally to the fresh data it actually received.
    With all members delivered at staleness 0 this reduces bitwise to the
    synchronous eq.-(2) weights (the parity-oracle path). An edge whose
    weight mass is zero keeps its model (the ``has_dev`` fixup).
    """
    w_dev = sizes.astype(jnp.float32)
    decay = (1.0 + staleness) ** a
    w_del = jnp.where(deliver_mask, w_dev / decay, 0.0)
    w_anchor = jnp.sum(jnp.where(member_mask & ~deliver_mask, w_dev, 0.0))
    tot = jnp.sum(w_del) + w_anchor
    denom = jnp.maximum(tot, 1.0)
    wn = w_del / denom
    wa = w_anchor / denom

    def agg(e, c):
        flat = c.reshape(c.shape[0], -1)
        new = wn @ flat + wa * e[m].reshape(-1)
        new = jnp.where(tot > 0, new, e[m].reshape(-1))
        return e.at[m].set(new.reshape(e.shape[1:]).astype(e.dtype))

    return jax.tree.map(agg, edge_params, cohort_params)


@functools.partial(jax.jit, static_argnames=("M",))
def _cloud_agg(edge_params, assign, sizes, *, M: int):
    """Eq. (3): cloud aggregation with cohort-data-size weights —
    identical op order to ``hfl_global_iteration_core``'s cloud path."""
    onehot = jax.nn.one_hot(assign, M, dtype=jnp.float32)
    w_dev = sizes.astype(jnp.float32)
    edge_tot = onehot.T @ w_dev
    w = jnp.where(edge_tot > 0, edge_tot, 0.0)
    w = w / jnp.maximum(jnp.sum(w), 1.0)

    def agg(e):
        flat = e.reshape(M, -1)
        return (w @ flat).reshape(e.shape[1:]).astype(e.dtype)

    return jax.tree.map(agg, edge_params)


@functools.partial(jax.jit, static_argnames=("M", "codec"))
def _cloud_agg_compressed(edge_params, global_params, assign, sizes, resid,
                          key, *, M: int, codec):
    """Compressed eq.-(3): each edge ships ``encode(edge - global)``, the
    cloud aggregates the decoded deltas in delta space (identical weights
    to ``_cloud_agg`` — exact when the codec is lossless). Returns
    ``(new_global, new_edge_resid)``."""
    onehot = jax.nn.one_hot(assign, M, dtype=jnp.float32)
    edge_tot = onehot.T @ sizes.astype(jnp.float32)
    w = jnp.where(edge_tot > 0, edge_tot, 0.0)
    w = w / jnp.maximum(jnp.sum(w), 1.0)

    delta = jax.tree.map(
        lambda e, g_: (e - g_[None]).astype(jnp.float32),
        edge_params, global_params)
    dec, new_resid = comp.encode_decode(codec, key, delta, resid)

    def agg(g_, d):
        flat = d.reshape(M, -1)
        return (g_.reshape(-1) + w @ flat).reshape(g_.shape).astype(g_.dtype)

    return jax.tree.map(agg, global_params, dec), new_resid


# ----------------------------------------------------------- the engine

@dataclasses.dataclass
class AsyncConfig:
    """Event-loop knobs. The defaults are the sync-parity setting:
    wait-for-all buffers, no jitter (pair with ``always_on`` traces)."""
    H: int = 20                     # scheduled cohort size
    arch: str = "hfl-cnn"           # model payload (configs.registry id)
    scheduler: str = "fedavg"       # fedavg | ikc | vkc
    K: int = 10                     # clusters (ikc/vkc)
    staleness_exp: float = 0.5      # a in D_n/(1+staleness)^a
    buffer_size: Optional[int] = None   # edge flush threshold; None =
                                        # wait for every in-flight member
    lr: float = 0.01
    alloc_steps: int = 100
    seed: int = 0
    jitter_sigma: float = 0.0       # per-task log-normal latency noise
    max_events_per_round: int = 100_000   # liveness guard
    compression: comp.CompressionConfig = dataclasses.field(
        default_factory=comp.CompressionConfig)


class AsyncHFLEngine:
    """Virtual-clock asynchronous HFL over an availability trace.

    ``step_round()`` runs ONE cloud round as a discrete-event loop:
    dispatch the scheduled cohort, deliver updates at trace-determined
    times, flush staleness-weighted edge buffers Q times per edge, then
    cloud-aggregate and advance the virtual clock by the round makespan.
    The model/scheduler setup mirrors ``HFLFramework`` (same
    ``cfg.arch``-resolved :class:`~repro.models.spec.ModelSpec`, same
    key derivation for the model init, same ``model_bits`` patching) so
    sync and async runs start from identical states for any payload.
    """

    def __init__(self, sp: cm.SystemParams, pop: cm.Population,
                 fed: FederatedData, cfg: AsyncConfig,
                 trace: Optional[cm.AvailabilityTrace] = None,
                 scheduler=None, assigner=None):
        self.pop, self.cfg, self.fed = pop, cfg, fed
        key = jax.random.PRNGKey(cfg.seed)
        k_model, _, _ = jax.random.split(key, 3)
        self.spec = get_hfl_spec(cfg.arch)
        self.model_params = self.spec.init_fn(k_model, fed)
        self.apply_fn = self.spec.apply_fn
        self.sp = dataclasses.replace(
            sp, model_bits=float(tree_bytes(self.model_params) * 8))
        self.codec = cfg.compression
        self.uplink_bits = comp.message_bits(self.codec, self.model_params)
        # allocation + pricing see the codec's actual bits-per-message;
        # codec="none" gives exactly model_bits, so sp_round equals
        # self.sp (same frozen dataclass -> same jit cache entry ->
        # bitwise sync parity).
        self.sp_round = dataclasses.replace(
            self.sp, model_bits=float(self.uplink_bits))
        self.dev_resid = comp.init_state(self.codec, self.model_params,
                                         fed.n_devices)
        self.edge_resid = comp.init_state(self.codec, self.model_params,
                                          pop.n_edges)
        self.X, self.y, self.mask = pad_device_data(fed)

        if scheduler is None:
            from repro.core.sweep import build_scheduler
            scheduler = build_scheduler(cfg.scheduler, fed, self.sp, cfg.H,
                                        K=cfg.K, lr=cfg.lr, seed=cfg.seed,
                                        arch=cfg.arch)
        self.scheduler = scheduler
        if assigner is None:
            from repro.core.assignment import GeoAssigner
            assigner = GeoAssigner(self.sp)
        self.assigner = assigner

        self.trace = trace or cm.AvailabilityTrace.always_on(pop.n_devices)
        assert self.trace.n_devices == pop.n_devices, \
            "availability trace / population size mismatch"
        self.rng = np.random.default_rng(cfg.seed)
        self.t = 0.0                    # virtual clock [s]
        self.round = 0
        self.history: List[Dict] = []
        self.last_sched: Optional[np.ndarray] = None
        self.last_assign: Optional[np.ndarray] = None
        self.last_alloc = None          # (b, f, tc, ec) of the last round

    # ------------------------------------------------------------ round

    def step_round(self, collect_eval: bool = True) -> Dict:
        sp, pop, cfg = self.sp, self.pop, self.cfg
        M, Q = pop.n_edges, sp.Q
        t0 = self.t

        sched = np.asarray(self.scheduler.schedule(self.rng))
        assign_np, _ = self.assigner.assign(pop, sched, self.rng)
        assign_np = np.asarray(assign_np)
        self.last_sched, self.last_assign = sched, assign_np
        H = len(sched)
        assign_j = jnp.asarray(assign_np, jnp.int32)
        sizes = pop.D[sched]

        b, f, tc, ec, T_cl, E_cl = _alloc_and_price(
            self.sp_round, pop.u[sched], pop.D[sched], pop.p[sched],
            pop.g[sched], pop.g_cloud, pop.B_m, assign_j, M=M,
            alloc_steps=cfg.alloc_steps)
        self.last_alloc = (b, f, tc, ec)
        ec_h = np.asarray(ec, np.float64)
        T_cl_h = np.asarray(T_cl, np.float64)
        lat = (np.asarray(tc, np.float64)
               * self.trace.latency_scale[sched])

        codec_on = self.codec.active
        cohort_resid, n_disp = None, 0
        if codec_on:
            cohort_resid = jax.tree.map(lambda r_: r_[sched],
                                        self.dev_resid)
            k_disp, k_cloud = jax.random.split(
                comp.round_key(self.codec, cfg.seed, self.round))

        Xc, yc, mc = self.X[sched], self.y[sched], self.mask[sched]
        edge_params = jax.tree.map(
            lambda g_: jnp.broadcast_to(g_[None], (M,) + g_.shape),
            self.model_params)
        cohort_params = jax.tree.map(
            lambda g_: jnp.broadcast_to(g_[None], (H,) + g_.shape),
            self.model_params)

        # --- per-slot event-loop state (cohort-indexed)
        up = self.trace.up_at(t0)[sched].copy()      # (H,) availability
        delivered = np.zeros(H, bool)                # in an edge buffer
        task_id = np.full(H, -1, np.int64)           # -1 = idle/aborted
        start_ver = np.zeros(H, np.int64)            # edge ver at dispatch
        edge_ver = np.zeros(M, np.int64)
        flushes = np.zeros(M, np.int64)
        edge_finish = np.full(M, t0, np.float64)
        edge_energy = np.zeros(M, np.float64)        # aggregated-task J
        members = [np.flatnonzero(assign_np == m) for m in range(M)]
        for m in range(M):                           # empty edges: done,
            if len(members[m]) == 0:                 # cloud hop only
                flushes[m] = Q
        stats = {"n_agg": 0, "n_stale": 0, "max_stale": 0,
                 "n_aborted": 0, "wasted_j": 0.0}

        heap: list = []
        seq = 0
        next_task = 0
        tog_rows = [self.trace.toggles[d] for d in sched]
        tog_ptr = [int(np.searchsorted(row, t0, side="right"))
                   for row in tog_rows]

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, payload))
            seq += 1

        for s in range(H):
            i = tog_ptr[s]
            if i < len(tog_rows[s]) and np.isfinite(tog_rows[s][i]):
                push(float(tog_rows[s][i]), "toggle", s)

        def dispatch(slots, t):
            nonlocal cohort_params, cohort_resid, n_disp, next_task
            slots = [s for s in slots
                     if up[s] and not delivered[s] and task_id[s] < 0
                     and flushes[assign_np[s]] < Q]
            if not slots:
                return
            dmask = np.zeros(H, bool)
            dmask[slots] = True
            if codec_on:
                cohort_params, cohort_resid = _train_dispatched_compressed(
                    self.apply_fn, cohort_params, edge_params, assign_j,
                    jnp.asarray(dmask), Xc, yc, mc, cfg.lr, cohort_resid,
                    jax.random.fold_in(k_disp, n_disp), L=sp.L,
                    codec=self.codec)
                n_disp += 1
            else:
                cohort_params = _train_dispatched(
                    self.apply_fn, cohort_params, edge_params, assign_j,
                    jnp.asarray(dmask), Xc, yc, mc, cfg.lr, L=sp.L)
            for s in slots:
                start_ver[s] = edge_ver[assign_np[s]]
                task_id[s] = next_task
                next_task += 1
                mult = 1.0
                if cfg.jitter_sigma > 0:
                    mult = float(np.exp(
                        self.rng.normal(0.0, cfg.jitter_sigma)))
                push(t + lat[s] * mult, "done", (s, task_id[s]))

        def do_flush(m, t, redispatch=True):
            nonlocal edge_params
            mem = members[m]
            del_mask = np.zeros(H, bool)
            del_mask[mem] = delivered[mem]
            mem_mask = np.zeros(H, bool)
            mem_mask[mem] = True
            stal = np.where(del_mask, edge_ver[m] - start_ver, 0)
            edge_params = _flush_edge(
                edge_params, cohort_params, jnp.int32(m),
                jnp.asarray(del_mask), jnp.asarray(mem_mask),
                sizes, jnp.asarray(stal, jnp.float32),
                jnp.float32(cfg.staleness_exp))
            d_slots = np.flatnonzero(del_mask)
            edge_energy[m] += float(ec_h[d_slots].sum())
            stats["n_agg"] += len(d_slots)
            if len(d_slots):
                s_max = int(stal[d_slots].max())
                stats["max_stale"] = max(stats["max_stale"], s_max)
                stats["n_stale"] += int((stal[d_slots] > 0).sum())
            delivered[d_slots] = False
            edge_ver[m] += 1
            flushes[m] += 1
            if flushes[m] >= Q:
                edge_finish[m] = t
            elif redispatch:
                dispatch(list(d_slots), t)

        def should_flush(m):
            if flushes[m] >= Q:
                return False
            mem = members[m]
            n_del = int(delivered[mem].sum())
            in_flight = int((task_id[mem] >= 0).sum())
            if n_del > 0 and in_flight == 0:
                return True          # buffer drained — nothing to wait on
            return (cfg.buffer_size is not None
                    and n_del >= min(cfg.buffer_size, len(mem)))

        # --- run the round
        dispatch(list(np.flatnonzero(up)), t0)
        events = 0
        while not np.all(flushes >= Q):
            if not heap or events >= cfg.max_events_per_round:
                break                # liveness guard: forced drain below
            t, _, kind, payload = heapq.heappop(heap)
            events += 1
            self.t = max(self.t, t)
            if kind == "toggle":
                s = payload
                tog_ptr[s] += 1
                i = tog_ptr[s]
                if i < len(tog_rows[s]) and np.isfinite(tog_rows[s][i]):
                    push(float(tog_rows[s][i]), "toggle", s)
                up[s] = not up[s]
                m = int(assign_np[s])
                if up[s]:
                    dispatch([s], t)         # mid-round arrival
                else:
                    if task_id[s] >= 0:      # dropout aborts in-flight
                        task_id[s] = -1
                        stats["wasted_j"] += float(ec_h[s])
                        stats["n_aborted"] += 1
                    if should_flush(m):
                        do_flush(m, t)
            else:                            # task completion
                s, tid = payload
                if tid != task_id[s]:
                    continue                 # aborted / superseded task
                task_id[s] = -1
                m = int(assign_np[s])
                if flushes[m] >= Q:          # edge already uploaded
                    stats["wasted_j"] += float(ec_h[s])
                    stats["n_aborted"] += 1
                    continue
                delivered[s] = True
                if should_flush(m):
                    do_flush(m, t)

        forced = int(np.maximum(Q - flushes, 0).sum())
        for m in range(M):                   # forced drain (liveness)
            while flushes[m] < Q:
                do_flush(m, self.t, redispatch=False)
        heap.clear()

        # --- round totals + eq.-(3) cloud aggregation
        T_m = (edge_finish - t0) + T_cl_h
        T_round = float(T_m.max()) if M else 0.0
        E_round = float(edge_energy.sum() + np.asarray(E_cl).sum())
        if codec_on:
            self.model_params, self.edge_resid = _cloud_agg_compressed(
                edge_params, self.model_params, assign_j, sizes,
                self.edge_resid, k_cloud, M=M, codec=self.codec)
            self.dev_resid = jax.tree.map(
                lambda full, r_: full.at[jnp.asarray(sched)].set(r_),
                self.dev_resid, cohort_resid)
        else:
            self.model_params = _cloud_agg(edge_params, assign_j, sizes,
                                           M=M)
        self.t = t0 + T_round
        self.round += 1

        acc = None
        if collect_eval:
            acc = evaluate_in_batches(self.apply_fn, self.model_params,
                                      self.fed.X_test, self.fed.y_test)
        rec = {"round": self.round, "t": self.t, "acc": acc,
               "T_i": T_round, "E_i": E_round,
               "obj_i": E_round + sp.lam * T_round,
               "H": H, "n_updates": stats["n_agg"],
               "n_stale": stats["n_stale"],
               "max_staleness": stats["max_stale"],
               "n_aborted": stats["n_aborted"],
               "wasted_j": stats["wasted_j"],
               "forced_flushes": forced,
               "msg_bits": cm.round_msg_bits(self.sp, stats["n_agg"], M,
                                             msg_bits=self.uplink_bits),
               "uplink_bytes": float(
                   (stats["n_agg"] + M) * self.uplink_bits / 8),
               "codec": self.codec.codec}
        self.history.append(rec)
        return rec

    # ------------------------------------------------------ conveniences

    def run(self, n_rounds: int, target_acc: Optional[float] = None,
            eval_every: int = 1, verbose: bool = False) -> Dict:
        for r in range(1, n_rounds + 1):
            rec = self.step_round(
                collect_eval=eval_every > 0 and r % eval_every == 0)
            if verbose:
                acc = "-" if rec["acc"] is None else f"{rec['acc']:.3f}"
                print(f"  [async] round {rec['round']:3d} t={rec['t']:9.1f}s"
                      f" acc={acc} updates={rec['n_updates']}"
                      f" stale={rec['n_stale']} wasted={rec['wasted_j']:.1f}J")
            if (target_acc is not None and rec["acc"] is not None
                    and rec["acc"] >= target_acc):
                break
        return self.summary()

    def summary(self) -> Dict:
        evals = [r for r in self.history if r["acc"] is not None]
        T = sum(r["T_i"] for r in self.history)
        E = sum(r["E_i"] for r in self.history)
        return {"rounds": len(self.history), "t_virtual": self.t,
                "final_acc": evals[-1]["acc"] if evals else None,
                "T": T, "E": E, "objective": E + self.sp.lam * T,
                "n_updates": sum(r["n_updates"] for r in self.history),
                "n_stale": sum(r["n_stale"] for r in self.history),
                "n_aborted": sum(r["n_aborted"] for r in self.history),
                "wasted_j": sum(r["wasted_j"] for r in self.history),
                "history": self.history}
