from repro.core.assignment.hfel import HFELAssigner, total_objective  # noqa: F401
from repro.core.assignment.geo import GeoAssigner  # noqa: F401
from repro.core.assignment.drl import DRLAssigner  # noqa: F401
