"""Geographical-distribution baseline: nearest edge (max mean gain)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm


def geo_assign_traced(dev_pos, edge_pos, sched_idx):
    """Traced twin of ``GeoAssigner.assign``: nearest edge per scheduled
    device, computed with jnp ops so the fused sweep scan can run the
    geographic assigner in-trace (one (H, M) distance panel per lane,
    vmap/shard_map-composable). dev_pos (N, 2), edge_pos (M, 2),
    sched_idx (H,) -> (H,) int32 edge ids. Ties break to the first
    minimum like ``np.argmin``; distances are f32 on device vs the
    host's f64, so a near-exact tie could in principle flip — sweeps
    are seeded, making any such flip deterministic per world."""
    d2 = jnp.sum(jnp.square(dev_pos[sched_idx][:, None] - edge_pos[None]),
                 axis=-1)
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


@dataclasses.dataclass
class GeoAssigner:
    sp: cm.SystemParams

    def assign(self, pop: cm.Population, sched_idx, rng=None):
        d = np.linalg.norm(pop.dev_pos[np.asarray(sched_idx)][:, None]
                           - pop.edge_pos[None], axis=-1)
        return np.argmin(d, axis=1), None
