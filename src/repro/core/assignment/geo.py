"""Geographical-distribution baseline: nearest edge (max mean gain)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model as cm


@dataclasses.dataclass
class GeoAssigner:
    sp: cm.SystemParams

    def assign(self, pop: cm.Population, sched_idx, rng=None):
        d = np.linalg.norm(pop.dev_pos[np.asarray(sched_idx)][:, None]
                           - pop.edge_pos[None], axis=-1)
        return np.argmin(d, axis=1), None
