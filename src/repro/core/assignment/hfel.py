"""HFEL [15] device-assignment search baseline.

Iterative local search over assignment patterns: *transfer* adjustments
(move one device to another edge) and *exchange* adjustments (swap two
devices between edges), each accepted iff it lowers the one-round
objective (17):

    J(Ψ) = Σ_m E_m(Ψ) + λ max_m T_m(Ψ)

where per-edge (T_m, E_m) come from the convex resource allocator
(problem 27) plus the constant cloud terms. The benchmark variants
HFEL-100/HFEL-300 bound the number of exchange trials as in §VI-B.

Two search engines share the move neighborhood:

* ``search="serial"`` — the literature-faithful accept/reject loop: one
  trial per step, each re-solving its two affected edges in one small
  ``allocate_batch`` call. Kept as the parity oracle
  (``tests/test_assignment.py`` pins batched quality against it).
* ``search="batched"`` (default) — the K-candidate round engine. Each
  round samples K moves *without replacement* from the current move
  neighborhood, materialises the 2K affected-edge membership masks,
  solves ALL of them in ONE ``allocate_batch`` dispatch (flat
  ``(K·2, H)`` layout via ``resource.flatten_trials`` /
  ``unflatten_trials``), scores all K objectives J(Ψ_k) in one
  vectorised pass, and commits up to ``accept_top`` non-conflicting
  improving moves in ΔJ order — the accept pass itself is a jitted
  sorted/masked ``lax.scan`` (``_accept_scan``), not a Python loop over
  the K candidates. Moves with disjoint affected-edge sets
  also move disjoint devices, so their per-edge solves compose exactly;
  each extra accept is re-verified against the exact combined objective
  before committing. A serial trial budget of n maps onto
  ``ceil(n / n_candidates)`` rounds, so HFEL-100/HFEL-300 keep their
  §VI-B trial counts while paying ~K× fewer jit dispatches — the
  latency gap the source paper (arXiv:2402.02506) holds against search
  baselines.

  Trial edges differ from the incumbent by a single moved device, so
  their re-solves are *warm-started* from the incumbent's per-edge
  solver iterates (``resource.allocate_batch_warm``) at ``warm_steps``
  Adam steps (default 40% of ``alloc_steps``) — cutting solver FLOPs,
  not just dispatch overhead, relative to cold serial trials.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import resource as ra

_TRANSFER, _EXCHANGE = 0, 1


def _objective(Tv, Ev, T_cl, E_cl, lam):
    """J(Ψ) (17) including the constant cloud terms. Works on numpy or
    jnp arrays, and reduces the trailing edge axis so it scores one (M,)
    pattern or a whole (K, M) candidate round. The single authoritative
    formula — shared by the host-side scoring in ``assign`` and the
    jitted accept scan, so the two can never diverge."""
    return (Ev + E_cl).sum(-1) + lam * (Tv + T_cl).max(-1)


def _accept_scan_core(J, edges, Tn, En, T0, E0, cur0, T_cl, E_cl, lam, valid,
                      *, accept_top: int):
    """Vectorised accept pass over one round's candidates, sorted by J.

    Replaces the host-side Python loop over ≤K moves with ONE jitted
    ``lax.scan`` carrying the incumbent per-edge (T, E) tables, the
    current objective, the set of already-touched edges (an (M,) mask)
    and the accept count. Inputs are ASCENDING-J sorted and padded to a
    fixed K (``valid`` masks the padding), so each (K, M) shape compiles
    once. Per candidate, in order:

    * improving — J beats the ROUND-START incumbent ``cur0`` (the sorted
      serial loop's early ``break``: every later candidate fails too);
    * blocked — an edge already touched by an accepted move, or the
      ``accept_top`` cap: emit a carry flag (re-proposed next round);
    * otherwise re-verify the EXACT combined objective against the
      carried tables and accept iff it beats the carried ``cur``.

    Returns (T, E, cur, accept_flags, carry_flags) — flags in the sorted
    order, committed to host state by the caller.
    """
    M = T0.shape[0]

    def step(carry, inp):
        T, E, cur, used, n_acc = carry
        j_i, e, t_i, e_i, v = inp
        improving = v & (j_i < cur0 - 1e-9)
        blocked = used[e[0]] | used[e[1]] | (n_acc >= accept_top)
        T_try = T.at[e].set(t_i)
        E_try = E.at[e].set(e_i)
        J_try = _objective(T_try, E_try, T_cl, E_cl, lam)
        accept = improving & ~blocked & (J_try < cur - 1e-9)
        T = jnp.where(accept, T_try, T)
        E = jnp.where(accept, E_try, E)
        cur = jnp.where(accept, J_try, cur)
        touched = (jnp.arange(M) == e[0]) | (jnp.arange(M) == e[1])
        used = used | (accept & touched)
        n_acc = n_acc + accept.astype(jnp.int32)
        return (T, E, cur, used, n_acc), (accept, improving & blocked)

    init = (T0, E0, cur0, jnp.zeros(M, bool), jnp.asarray(0, jnp.int32))
    (T, E, cur, _, _), (acc, car) = jax.lax.scan(
        step, init, (J, edges, Tn, En, valid))
    return T, E, cur, acc, car


_accept_scan = functools.partial(jax.jit, static_argnames=("accept_top",))(
    _accept_scan_core)


@functools.partial(jax.jit, static_argnames=("accept_top",))
def _accept_scan_pops(J, edges, Tn, En, T0, E0, cur0, T_cl, E_cl, lam, valid,
                      *, accept_top: int):
    """``_accept_scan`` vmapped over a leading population axis: one
    dispatch commits every episode population's round of a lockstep
    ``assign_batch`` wave. All inputs gain an (E,) axis (``lam``
    included, so the vmap axes stay uniform); outputs mirror
    ``_accept_scan`` with the same leading axis."""
    return jax.vmap(
        functools.partial(_accept_scan_core, accept_top=accept_top)
    )(J, edges, Tn, En, T0, E0, cur0, T_cl, E_cl, lam, valid)


def _round_plan(n_transfer: int, n_exchange: int, K: int):
    """Static per-round (kind, budget) plan of the K-candidate search:
    ``ceil(n_transfer/K)`` transfer rounds then ``ceil(n_exchange/K)``
    exchange rounds, the last round of each phase carrying the remainder
    budget — the same trial accounting as the host engines' while loops,
    laid out as arrays so a ``lax.scan`` can consume it."""
    kinds, budgets = [], []
    for kind, budget in ((_TRANSFER, n_transfer), (_EXCHANGE, n_exchange)):
        remaining = int(budget)
        while remaining > 0:
            k = min(K, remaining)
            remaining -= k
            kinds.append(kind)
            budgets.append(k)
    return np.asarray(kinds, np.int32), np.asarray(budgets, np.int32)


def hfel_search_traced(sp: cm.SystemParams, u, D, p, g, B_m, g_cloud, key,
                       *, n_transfer: int = 40, n_exchange: int = 80,
                       n_candidates: int = 16, alloc_steps: int = 100,
                       warm_steps: Optional[int] = None,
                       accept_top: int = 4):
    """Fully-traced K-candidate HFEL search — the fused sweep scan's
    assignment engine (``SweepRunner.run(assign="hfel", fused=True)``).

    Same move neighborhood, warm-started trial solves and sorted accept
    pass as ``HFELAssigner._search_batched``, but every stage — proposal
    sampling, candidate-assignment scatter, trial-array assembly, the
    accept commit — is jnp ops under one ``lax.scan`` over the static
    ``_round_plan``, so the whole search composes with ``vmap`` (one
    search per sweep lane) and ``shard_map`` with zero host round-trips.
    Differences from the host engine, by design:

    * proposals draw from the JAX PRNG ``key`` (one split per round),
      not a numpy Generator — decisions match the host engine in
      *distribution*, not bitwise;
    * no carry-over list: an improving-but-blocked move is simply
      re-proposable in a later round (a data-dependent carry list cannot
      live in a fixed-shape scan). Quality parity with the host engine
      is pinned statistically in ``tests/test_sweep_fused.py``.

    u/D/p (H,) cohort features, g (H, M) cohort gains, B_m (M,),
    g_cloud (M,), key a PRNG key. Returns (assign (H,) int32, J scalar)
    like ``HFELAssigner.assign``.
    """
    H, M = g.shape
    K = max(1, int(n_candidates))
    if K > min(H * M, H * H):
        raise ValueError(f"n_candidates={K} exceeds the move "
                         f"neighborhood (H={H}, M={M})")
    warm = warm_steps or max(25, (2 * alloc_steps) // 5)
    T_cl, E_cl = cm.cloud_cost(sp, g_cloud)
    T_cl = jnp.asarray(T_cl, jnp.float32)
    E_cl = jnp.asarray(E_cl, jnp.float32)
    lam = jnp.asarray(sp.lam, jnp.float32)
    gT = jnp.asarray(g).T                                   # (M, H)
    assign0 = jnp.argmax(g, axis=1).astype(jnp.int32)

    # cold solve of all M incumbent edges at full fidelity
    edge_ids = jnp.arange(M)
    res0, (tb0, tf0) = ra.allocate_batch_warm(
        sp, jnp.broadcast_to(u, (M, H)), jnp.broadcast_to(D, (M, H)),
        jnp.broadcast_to(p, (M, H)), gT, jnp.asarray(B_m),
        assign0[None, :] == edge_ids[:, None],
        jnp.zeros((M, H), jnp.float32), jnp.ones((M, H), jnp.float32),
        steps=alloc_steps)
    T0 = jnp.asarray(res0.T_edge, jnp.float32)
    E0 = jnp.asarray(res0.E_edge, jnp.float32)
    cur0 = jnp.asarray(_objective(T0, E0, T_cl, E_cl, lam), jnp.float32)

    kinds, budgets = _round_plan(n_transfer, n_exchange, K)
    rowsK = jnp.arange(K)

    def round_step(carry, xs):
        assign, T, E, tb, tf, cur, key = carry
        kind, k_budget = xs
        key, k_t, k_e = jax.random.split(key, 3)
        # both proposal kinds are drawn branchlessly and selected on the
        # (traced) round kind — the unused draw is cheap (two argsorts)
        raw_t = jax.random.permutation(k_t, H * M)[:K]
        h_t, dst = raw_t // M, raw_t % M
        ok_t = assign[h_t] != dst
        raw_e = jax.random.permutation(k_e, H * H)[:K]
        h1, h2 = raw_e // H, raw_e % H
        ok_e = (h1 != h2) & (assign[h1] != assign[h2])
        is_t = kind == _TRANSFER
        # unified move layout: device d0 -> edge v0, device d1 -> edge v1
        # (transfer: d0 == d1 == the moved device), affected edges (e0, e1)
        d0 = jnp.where(is_t, h_t, h1)
        d1 = jnp.where(is_t, h_t, h2)
        v0 = jnp.where(is_t, dst, assign[h2]).astype(assign.dtype)
        v1 = jnp.where(is_t, dst, assign[h1]).astype(assign.dtype)
        e0 = jnp.where(is_t, assign[h_t], assign[h1])
        e1 = jnp.where(is_t, dst, assign[h2])
        valid = jnp.where(is_t, ok_t, ok_e) & (rowsK < k_budget)

        cand = jnp.repeat(assign[None], K, axis=0)
        cand = cand.at[rowsK, d0].set(v0).at[rowsK, d1].set(v1)
        edges = jnp.stack([e0, e1], axis=1)                 # (K, 2)
        masks = cand[:, None, :] == edges[:, :, None]       # (K, 2, H)
        flat = ra.flatten_trials(
            jnp.broadcast_to(u, (K, 2, H)), jnp.broadcast_to(D, (K, 2, H)),
            jnp.broadcast_to(p, (K, 2, H)), gT[edges],
            jnp.asarray(B_m)[edges], masks, tb[edges], tf[edges])
        res, (tb_f, tf_f) = ra.allocate_batch_warm(sp, *flat, steps=warm)
        res = ra.unflatten_trials(res, K, 2)
        Tn = jnp.asarray(res.T_edge, jnp.float32)           # (K, 2)
        En = jnp.asarray(res.E_edge, jnp.float32)
        tb_n = tb_f.reshape(K, 2, H)
        tf_n = tf_f.reshape(K, 2, H)

        T2 = jnp.repeat(T[None], K, axis=0).at[rowsK[:, None], edges].set(Tn)
        E2 = jnp.repeat(E[None], K, axis=0).at[rowsK[:, None], edges].set(En)
        J = jnp.where(valid, _objective(T2, E2, T_cl, E_cl, lam), jnp.inf)
        order = jnp.argsort(J)
        T_out, E_out, cur_out, acc, _ = _accept_scan_core(
            J[order], edges[order], Tn[order], En[order], T, E, cur,
            T_cl, E_cl, lam, valid[order], accept_top=accept_top)

        # commit accepted moves; accepted sets are edge-disjoint hence
        # device-disjoint, so round-start (d, v) values compose exactly
        def commit(i, st):
            a_, tb_, tf_ = st
            idx = order[i]
            on = acc[i]
            a_ = a_.at[d0[idx]].set(jnp.where(on, v0[idx], a_[d0[idx]]))
            a_ = a_.at[d1[idx]].set(jnp.where(on, v1[idx], a_[d1[idx]]))
            tb_ = tb_.at[edges[idx]].set(
                jnp.where(on, tb_n[idx], tb_[edges[idx]]))
            tf_ = tf_.at[edges[idx]].set(
                jnp.where(on, tf_n[idx], tf_[edges[idx]]))
            return a_, tb_, tf_

        assign, tb, tf = jax.lax.fori_loop(0, K, commit, (assign, tb, tf))
        return (assign, T_out, E_out, tb, tf, cur_out, key), None

    carry0 = (assign0, T0, E0, jnp.asarray(tb0), jnp.asarray(tf0),
              cur0, key)
    (assign, _, _, _, _, cur, _), _ = jax.lax.scan(
        round_step, carry0, (jnp.asarray(kinds), jnp.asarray(budgets)))
    return assign, cur


hfel_search_traced_jit = functools.partial(jax.jit, static_argnames=(
    "sp", "n_transfer", "n_exchange", "n_candidates", "alloc_steps",
    "warm_steps", "accept_top"))(hfel_search_traced)


def _edges_eval_warm(sp, feats, assign, edges, B, steps, tb0, tf0):
    """Resource-allocate a subset of edges in ONE batched jit call.

    feats: dict of (H,)/(H, M) cohort arrays; edges: edge ids to solve;
    tb0/tf0: (len(edges), H) warm-start iterates — neutral (zeros/ones)
    iterates make this numerically the cold solve. Returns (T, E, tb,
    tf): per-edge costs excluding cloud constants (added by callers)
    plus the final iterates so callers can maintain warm-start caches.
    """
    edges = np.asarray(edges)
    k = len(edges)
    H = feats["u"].shape[0]
    masks = np.asarray(assign)[None, :] == edges[:, None]
    res, (tb, tf) = ra.allocate_batch_warm(
        sp,
        np.broadcast_to(np.asarray(feats["u"]), (k, H)),
        np.broadcast_to(np.asarray(feats["D"]), (k, H)),
        np.broadcast_to(np.asarray(feats["p"]), (k, H)),
        np.asarray(feats["g"])[:, edges].T, np.asarray(B)[edges], masks,
        np.asarray(tb0), np.asarray(tf0), steps=steps)
    return (np.asarray(res.T_edge), np.asarray(res.E_edge),
            np.asarray(tb), np.asarray(tf))


def _edges_eval(sp, feats, assign, edges: Sequence[int], B,
                alloc_steps: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cold ``_edges_eval_warm`` returning just the (T, E) costs — the
    serial oracle's per-trial solve."""
    k = len(np.asarray(edges))
    H = feats["u"].shape[0]
    T, E, _, _ = _edges_eval_warm(sp, feats, assign, edges, B, alloc_steps,
                                  np.zeros((k, H), np.float32),
                                  np.ones((k, H), np.float32))
    return T, E


def _trial_arrays(feats, assigns, edges, B, tb0, tf0, pad_to: int = 0):
    """Build one round's padded trial-major allocation inputs.

    assigns: (k, H) candidate assignment per move; edges: (k, E)
    affected edge ids per move; tb0/tf0: (k, E, H) warm-start iterates.
    ``pad_to > k`` pads the trial axis by repeating rows so every round
    shares one compiled (pad_to·E, H) program regardless of how many
    proposals survived validity filtering. Returns
    ((u, D, p, g, B_k, masks, tb0, tf0), k) — trial-major arrays in
    ``flatten_trials`` argument order plus the true (unpadded) trial
    count.
    """
    assigns = np.asarray(assigns)
    edges = np.asarray(edges)
    tb0, tf0 = np.asarray(tb0), np.asarray(tf0)
    k = edges.shape[0]
    if pad_to > k:
        pad = pad_to - k
        rep = lambda a: np.concatenate([a, np.repeat(a[:1], pad, 0)])  # noqa: E731
        assigns, edges, tb0, tf0 = map(rep, (assigns, edges, tb0, tf0))
    K, n_aff = edges.shape
    H = assigns.shape[1]
    # pure numpy assembly: building trial arrays op-by-op on device costs
    # one dispatch per op per population — at wave scale (rounds x E pops)
    # that host overhead was larger than the solves. One transfer happens
    # at the jitted allocate call instead.
    masks = assigns[:, None, :] == edges[:, :, None]
    g = np.asarray(feats["g"]).T[edges]                        # (K, E, H)
    u = np.broadcast_to(np.asarray(feats["u"]), (K, n_aff, H))
    D = np.broadcast_to(np.asarray(feats["D"]), (K, n_aff, H))
    p = np.broadcast_to(np.asarray(feats["p"]), (K, n_aff, H))
    B_k = np.asarray(B)[edges]                                 # (K, E)
    return (u, D, p, g, B_k, masks, tb0, tf0), k


def _trials_eval(sp, feats, assigns, edges, B, steps: int, tb0, tf0,
                 pad_to: int = 0):
    """Solve the affected edges of K candidate moves in ONE batched call.

    Trial-major inputs as in ``_trial_arrays`` (each trial differs from
    its incumbent by one moved device, so ``steps`` can be a fraction of
    the cold-start count); everything is flattened to ``allocate_batch``'s
    flat (K·E, H) layout, solved in one ``allocate_batch_warm`` call and
    unflattened back to move-major arrays.

    Returns (T, E, tb, tf): (k, E) costs excluding cloud constants plus
    the (k, E, H) final iterates for cache maintenance on accept.
    """
    arrs, k = _trial_arrays(feats, assigns, edges, B, tb0, tf0, pad_to)
    K, n_aff = arrs[4].shape
    H = arrs[0].shape[2]
    flat = ra.flatten_trials(*arrs)
    res, (tb, tf) = ra.allocate_batch_warm(sp, *flat, steps=steps)
    res = ra.unflatten_trials(res, K, n_aff)
    unflat = lambda a: np.asarray(a).reshape(K, n_aff, H)[:k]  # noqa: E731
    return (np.asarray(res.T_edge)[:k], np.asarray(res.E_edge)[:k],
            unflat(tb), unflat(tf))


def _edges_eval_warm_pops(sp, feats_e, assign_e, B_e, steps: int, tb0, tf0):
    """``_edges_eval_warm`` over E populations' full edge sets at once.

    feats_e/assign_e/B_e: per-population cohort dicts, assignments and
    bandwidths; tb0/tf0: (E, M, H) warm-start iterates. Population e's
    (M, H) edge problems occupy rows [e·M, (e+1)·M) of the flat batch —
    ONE ``allocate_batch_warm`` dispatch instead of E. Returns
    (T (E, M), E (E, M), tb (E, M, H), tf (E, M, H)).
    """
    E_pop = len(feats_e)
    H = feats_e[0]["u"].shape[0]
    M = len(np.asarray(B_e[0]))
    edge_ids = np.arange(M)
    parts = []
    for feats, assign, B in zip(feats_e, assign_e, B_e):
        masks = np.asarray(assign)[None, :] == edge_ids[:, None]
        parts.append((np.broadcast_to(np.asarray(feats["u"]), (M, H)),
                      np.broadcast_to(np.asarray(feats["D"]), (M, H)),
                      np.broadcast_to(np.asarray(feats["p"]), (M, H)),
                      np.asarray(feats["g"]).T, np.asarray(B), masks))
    cat = [np.concatenate([p[i] for p in parts]) for i in range(6)]
    res, (tb, tf) = ra.allocate_batch_warm(
        sp, *cat, np.reshape(tb0, (E_pop * M, H)),
        np.reshape(tf0, (E_pop * M, H)), steps=steps)
    return (np.asarray(res.T_edge).reshape(E_pop, M),
            np.asarray(res.E_edge).reshape(E_pop, M),
            np.asarray(tb).reshape(E_pop, M, H),
            np.asarray(tf).reshape(E_pop, M, H))


def total_objective(sp: cm.SystemParams, pop: cm.Population, sched_idx,
                    assign, alloc_steps: int = 200
                    ) -> Tuple[float, np.ndarray, np.ndarray]:
    """J(Ψ) for a full assignment; returns (J, T_m array, E_m array)."""
    res = ra.allocate_all_edges(sp, pop, sched_idx, assign,
                                steps=alloc_steps)
    T_cl, E_cl = cm.cloud_cost(sp, pop.g_cloud)
    T_m = np.asarray(res.T_edge) + np.asarray(T_cl)
    E_m = np.asarray(res.E_edge) + np.asarray(E_cl)
    return float(E_m.sum() + sp.lam * T_m.max()), T_m, E_m


def _apply_move(assign: np.ndarray, move) -> np.ndarray:
    """New assignment after one transfer/exchange move (copy)."""
    kind, x, y = move
    na = assign.copy()
    if kind == _TRANSFER:
        na[x] = y
    else:
        na[x], na[y] = assign[y], assign[x]
    return na


def _move_edges(assign: np.ndarray, move) -> Tuple[int, int]:
    """The two edges whose membership a move changes."""
    kind, x, y = move
    return (int(assign[x]), int(y)) if kind == _TRANSFER else \
        (int(assign[x]), int(assign[y]))


@dataclasses.dataclass
class _BatchedState:
    """Incumbent of the batched search: assignment, per-edge (T, E)
    caches, and the per-edge solver iterates seeding warm re-solves."""
    assign: np.ndarray   # (H,) current edge per scheduled device
    T: np.ndarray        # (M,) cached per-edge delays
    E: np.ndarray        # (M,) cached per-edge energies
    tb: np.ndarray       # (M, H) bandwidth-logit iterates
    tf: np.ndarray       # (M, H) frequency iterates
    cur: float = np.inf  # objective J of the incumbent


@dataclasses.dataclass
class HFELAssigner:
    sp: cm.SystemParams
    n_transfer: int = 100
    n_exchange: int = 300
    alloc_steps: int = 200
    search: str = "batched"        # "batched" | "serial" (oracle)
    n_candidates: int = 16         # K: trials per batched round
    accept_top: int = 4            # max non-conflicting accepts per round
    warm_steps: Optional[int] = None   # trial re-solve steps (None: 40%)

    def assign(self, pop: cm.Population, sched_idx: np.ndarray,
               rng: np.random.Generator,
               init_assign: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, float]:
        if self.search not in ("batched", "serial"):
            raise ValueError(f"unknown HFEL search engine: {self.search!r}")
        sched_idx = np.asarray(sched_idx)
        H = len(sched_idx)
        M = pop.n_edges
        feats, B, T_cl, E_cl, assign = self._cohort(pop, sched_idx,
                                                    init_assign)

        obj = functools.partial(_objective, T_cl=T_cl, E_cl=E_cl,
                                lam=self.sp.lam)

        if self.search == "serial":
            return self._search_serial(feats, B, obj, assign, rng, H, M)
        return self._search_batched(feats, B, obj, assign, rng, H, M,
                                    T_cl, E_cl)

    def _cohort(self, pop: cm.Population, sched: np.ndarray,
                init_assign: Optional[np.ndarray]):
        """Host-side numpy cohort of one population: feature dict,
        bandwidths, cloud constants and the initial (best-gain or
        caller-provided) assignment. Numpy throughout so trial-array
        assembly never pays per-op device dispatches (one transfer at
        each jitted solve). Shared by ``assign`` and ``assign_batch``
        so the two engines can never diverge on setup."""
        g = np.asarray(pop.g)[sched]
        feats = {"u": np.asarray(pop.u)[sched],
                 "D": np.asarray(pop.D)[sched],
                 "p": np.asarray(pop.p)[sched], "g": g}
        T_cl, E_cl = cm.cloud_cost(self.sp, pop.g_cloud)
        if init_assign is None:
            assign = np.asarray(np.argmax(g, axis=1))
        else:
            assign = np.asarray(init_assign).copy()
        return (feats, np.asarray(pop.B_m), np.asarray(T_cl),
                np.asarray(E_cl), assign)

    # ----------------------------------------- lockstep population waves

    def assign_batch(self, pops, sched_idx, rngs,
                     init_assigns: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Search E populations' assignments in lockstep waves — the
        batched imitation-target generator of the D3QN trainer (Alg. 5)
        and the multi-population path of ``fig6_assignment``.

        pops: a ``cost_model.PopulationBatch`` or a sequence of
        same-shape ``Population``s; sched_idx: one shared (H,) schedule
        or per-population (E, H) schedules; rngs: one
        ``np.random.Generator`` (or int seed) per population, consumed
        exactly as E independent ``assign`` calls would consume them.

        Under ``search="batched"`` every wave proposes K candidate moves
        *per population*, solves ALL populations' affected edges in ONE
        ``allocate_batch_warm`` dispatch (E·K·2 edge problems through
        ``resource.flatten_trials``) and commits accepts through ONE
        vmapped accept scan — a wave costs the dispatch count of a
        single population's round, which is where the batched trainer's
        episodes/sec comes from. Population e's search visits the same
        proposals, solves and accepts as ``assign(pops[e], sched,
        rngs[e])`` (pinned in ``tests/test_drl_engine.py``).
        ``search="serial"`` falls back to E independent oracle searches.

        Returns (assigns (E, H), objectives (E,)).
        """
        if self.search not in ("batched", "serial"):
            raise ValueError(f"unknown HFEL search engine: {self.search!r}")
        pop_list = (pops.populations() if hasattr(pops, "populations")
                    else list(pops))
        E_pop = len(pop_list)
        rngs = [r if isinstance(r, np.random.Generator)
                else np.random.default_rng(r) for r in rngs]
        sched_idx = np.asarray(sched_idx)
        if sched_idx.ndim == 1:
            scheds = np.broadcast_to(sched_idx, (E_pop, len(sched_idx)))
        else:
            scheds = sched_idx

        if self.search == "serial":
            outs = [self.assign(pop, scheds[e], rngs[e],
                                None if init_assigns is None
                                else init_assigns[e])
                    for e, pop in enumerate(pop_list)]
            return (np.stack([o[0] for o in outs]),
                    np.array([o[1] for o in outs]))

        H = scheds.shape[1]
        M = pop_list[0].n_edges
        K = max(1, int(self.n_candidates))
        warm = self.warm_steps or max(25, (2 * self.alloc_steps) // 5)

        feats_e, B_e, Tcl_e, Ecl_e, assigns0 = [], [], [], [], []
        for e, pop in enumerate(pop_list):
            feats, B, T_cl, E_cl, assign0 = self._cohort(
                pop, scheds[e],
                None if init_assigns is None else init_assigns[e])
            feats_e.append(feats)
            B_e.append(B)
            Tcl_e.append(T_cl)
            Ecl_e.append(E_cl)
            assigns0.append(assign0)

        # all E*M edges in one full-fidelity cold solve
        T0, E0, tb0, tf0 = _edges_eval_warm_pops(
            self.sp, feats_e, assigns0, B_e, self.alloc_steps,
            np.zeros((E_pop, M, H), np.float32),
            np.ones((E_pop, M, H), np.float32))
        states = []
        for e in range(E_pop):
            st = _BatchedState(assigns0[e], T0[e], E0[e],
                               np.array(tb0[e]), np.array(tf0[e]))
            st.cur = float(_objective(st.T, st.E, Tcl_e[e], Ecl_e[e],
                                      self.sp.lam))
            states.append(st)
        # population-stacked cohort arrays: every wave round assembles
        # its trial batch with whole-(E, K, 2, ...) numpy ops on these
        stk = {"u": np.stack([f["u"] for f in feats_e]),
               "D": np.stack([f["D"] for f in feats_e]),
               "p": np.stack([f["p"] for f in feats_e]),
               "gT": np.stack([f["g"].T for f in feats_e]),   # (E, M, H)
               "B": np.stack(B_e),
               "Tcl": np.stack(Tcl_e), "Ecl": np.stack(Ecl_e)}

        for kind, budget in ((_TRANSFER, self.n_transfer),
                             (_EXCHANGE, self.n_exchange)):
            remaining = int(budget)
            carries: List[List[tuple]] = [[] for _ in range(E_pop)]
            while remaining > 0:
                k = min(K, remaining)
                remaining -= k
                moves_e = [self._propose(rngs[e], states[e].assign, H, M,
                                         k, kind, carries[e])
                           for e in range(E_pop)]
                carries = self._round_pops(moves_e, stk, states, K, warm)
        return (np.stack([st.assign for st in states]),
                np.array([st.cur for st in states]))

    def _round_pops(self, moves_e, stk, states, K, warm_steps
                    ) -> List[List[tuple]]:
        """One lockstep wave round: every population's K candidates
        solved in a single ``allocate_batch_warm`` dispatch and
        committed through one vmapped accept scan.

        The trial batch is assembled with whole-array numpy ops over the
        population-stacked cohort ``stk`` — no per-population device
        work (at wave scale the op-by-op assembly overhead used to
        exceed the solves themselves). A population that proposed fewer
        than K valid moves (or none) pads with incumbent rows that are
        solved but marked invalid, so every wave shares one compiled
        program. Returns the per-population carry lists.
        """
        E_pop = len(states)
        H = states[0].assign.shape[0]
        ns = np.array([len(m) for m in moves_e])
        cand = np.empty((E_pop, K, H), states[0].assign.dtype)
        edges = np.zeros((E_pop, K, 2), np.int64)
        for e, (moves, st) in enumerate(zip(moves_e, states)):
            cand[e] = st.assign            # padding rows: incumbent, edge 0
            for i, mv in enumerate(moves):
                cand[e, i] = _apply_move(st.assign, mv)
                edges[e, i] = _move_edges(st.assign, mv)

        eE = np.arange(E_pop)[:, None, None]
        masks = cand[:, :, None, :] == edges[:, :, :, None]     # (E,K,2,H)
        g = stk["gT"][eE, edges]                                # (E,K,2,H)
        u = np.broadcast_to(stk["u"][:, None, None, :], masks.shape)
        D = np.broadcast_to(stk["D"][:, None, None, :], masks.shape)
        p = np.broadcast_to(stk["p"][:, None, None, :], masks.shape)
        B_k = stk["B"][eE, edges]                               # (E,K,2)
        tb0 = np.stack([st.tb for st in states])[eE, edges]     # (E,K,2,H)
        tf0 = np.stack([st.tf for st in states])[eE, edges]

        def fl(a):                 # (E, K, 2, ...) -> trial-major (E*K, 2, ...)
            return a.reshape((E_pop * K,) + a.shape[2:])

        flat = ra.flatten_trials(fl(u), fl(D), fl(p), fl(g), fl(B_k),
                                 fl(masks), fl(tb0), fl(tf0))
        res, (tb, tf) = ra.allocate_batch_warm(self.sp, *flat,
                                               steps=warm_steps)
        Tn = np.asarray(res.T_edge).reshape(E_pop, K, 2)
        En = np.asarray(res.E_edge).reshape(E_pop, K, 2)
        tb_n = np.asarray(tb).reshape(E_pop, K, 2, H)
        tf_n = np.asarray(tf).reshape(E_pop, K, 2, H)

        # score all E*K candidate objectives in one vectorised pass
        T_inc = np.stack([st.T for st in states])               # (E, M)
        E_inc = np.stack([st.E for st in states])
        T2 = np.repeat(T_inc[:, None], K, axis=1)               # (E, K, M)
        E2 = np.repeat(E_inc[:, None], K, axis=1)
        kK = np.arange(K)[None, :, None]
        T2[eE, kK, edges] = Tn
        E2[eE, kK, edges] = En
        J = np.asarray(_objective(T2, E2, stk["Tcl"][:, None],
                                  stk["Ecl"][:, None], self.sp.lam))
        valid = np.arange(K)[None] < ns[:, None]                # (E, K)
        J = np.where(valid, J, np.inf)                          # pad rows last
        order = np.argsort(J, axis=1)

        def srt(a):
            ix = order.reshape(E_pop, K, *([1] * (a.ndim - 2)))
            return np.take_along_axis(a, ix, axis=1)

        T_out, E_out, cur, acc, car = _accept_scan_pops(
            jnp.asarray(np.take_along_axis(J, order, axis=1)),
            jnp.asarray(srt(edges)), jnp.asarray(srt(Tn)),
            jnp.asarray(srt(En)), jnp.asarray(T_inc), jnp.asarray(E_inc),
            jnp.asarray(np.array([st.cur for st in states], np.float32)),
            jnp.asarray(stk["Tcl"]), jnp.asarray(stk["Ecl"]),
            jnp.full((E_pop,), self.sp.lam, jnp.float32),
            jnp.asarray(valid), accept_top=self.accept_top)
        acc, car = np.asarray(acc), np.asarray(car)
        T_out, E_out, cur = (np.asarray(T_out), np.asarray(E_out),
                             np.asarray(cur))

        carries: List[List[tuple]] = []
        for e in range(E_pop):
            st = states[e]
            moves = moves_e[e]
            carry: List[tuple] = []
            for pos in range(ns[e]):
                i = order[e, pos]
                if acc[e, pos]:
                    st.assign = _apply_move(st.assign, moves[i])
                    st.tb[edges[e, i]] = tb_n[e, i]
                    st.tf[edges[e, i]] = tf_n[e, i]
                elif car[e, pos]:
                    carry.append(moves[i])
            if acc[e, :ns[e]].any():
                st.T, st.E = T_out[e].copy(), E_out[e].copy()
                st.cur = float(cur[e])
            carries.append(carry)
        return carries

    # ------------------------------------------------------ serial oracle

    def _search_serial(self, feats, B, obj, assign, rng, H, M):
        """One-trial-at-a-time accept/reject loop (original HFEL)."""
        # per-edge cached terms — all M edges in one batched solve
        T, E = _edges_eval(self.sp, feats, assign, np.arange(M), B,
                           self.alloc_steps)
        cur = float(obj(T, E))

        def try_move(new_assign, edges):
            nonlocal cur, assign, T, E
            T2, E2 = T.copy(), E.copy()
            edges = list(edges)
            T2[edges], E2[edges] = _edges_eval(self.sp, feats, new_assign,
                                               edges, B, self.alloc_steps)
            new = float(obj(T2, E2))
            if new < cur - 1e-9:
                assign, T, E, cur = new_assign, T2, E2, new
                return True
            return False

        # ---- transfer adjustments
        for _ in range(self.n_transfer):
            h = rng.integers(H)
            src = assign[h]
            dst = rng.integers(M)
            if dst == src:
                continue
            na = assign.copy()
            na[h] = dst
            try_move(na, (src, dst))

        # ---- exchange adjustments
        for _ in range(self.n_exchange):
            h1, h2 = rng.integers(H), rng.integers(H)
            m1, m2 = assign[h1], assign[h2]
            if m1 == m2:
                continue
            na = assign.copy()
            na[h1], na[h2] = m2, m1
            try_move(na, (m1, m2))

        return assign, cur

    # -------------------------------------------------- batched K-rounds

    def _propose(self, rng, assign, H, M, k, kind,
                 carry: List[tuple]) -> List[tuple]:
        """Assemble one round of k trial moves: carried-over moves first
        (improving last round but conflicting with an accepted move —
        still promising, so they spend this round's budget ahead of
        fresh draws), topped up with fresh proposals sampled without
        replacement from the move neighborhood of ``assign``.

        Like the serial loop, invalid draws (self-transfer, same-edge
        exchange) consume trial budget without an allocator call, so a
        budget of n means n raw trials under either engine.
        """
        moves = [mv for mv in carry
                 if _move_edges(assign, mv)[0] != _move_edges(assign, mv)[1]
                 ][:k]
        seen = {mv[1:] if mv[0] == _EXCHANGE else mv for mv in moves}
        fresh = k - len(moves)
        if fresh <= 0:
            return moves
        if kind == _TRANSFER:                      # (device h, dest edge)
            raw = rng.choice(H * M, size=min(fresh, H * M), replace=False)
            h, dst = raw // M, raw % M
            ok = assign[h] != dst
            for a, b in zip(h[ok], dst[ok]):
                mv = (_TRANSFER, int(a), int(b))
                if mv not in seen:
                    seen.add(mv)
                    moves.append(mv)
            return moves
        # exchange: ordered (h1, h2) like the serial draws, then
        # canonicalised so a round never evaluates the same swap twice
        raw = rng.choice(H * H, size=min(fresh, H * H), replace=False)
        h1, h2 = raw // H, raw % H
        ok = (h1 != h2) & (assign[h1] != assign[h2])
        for a, b in zip(h1[ok], h2[ok]):
            key = (int(min(a, b)), int(max(a, b)))
            if key not in seen:
                seen.add(key)
                moves.append((_EXCHANGE, key[0], key[1]))
        return moves

    def _search_batched(self, feats, B, obj, assign, rng, H, M, T_cl, E_cl):
        K = max(1, int(self.n_candidates))
        warm = self.warm_steps or max(25, (2 * self.alloc_steps) // 5)
        # all M edges in one full-fidelity solve; neutral iterates make
        # it the cold solve, and its final iterates seed the warm caches
        T0, E0, tb0, tf0 = _edges_eval_warm(
            self.sp, feats, assign, np.arange(M), B, self.alloc_steps,
            np.zeros((M, H), np.float32), np.ones((M, H), np.float32))
        # np.array: jax buffers are read-only views; caches are written
        st = _BatchedState(assign, T0, E0, np.array(tb0), np.array(tf0))
        st.cur = float(obj(st.T, st.E))
        for kind, budget in ((_TRANSFER, self.n_transfer),
                             (_EXCHANGE, self.n_exchange)):
            remaining = int(budget)
            carry: List[tuple] = []
            while remaining > 0:
                k = min(K, remaining)
                remaining -= k
                moves = self._propose(rng, st.assign, H, M, k, kind, carry)
                if moves:
                    carry = self._round(moves, feats, B, obj, st, K, warm,
                                        T_cl, E_cl)
        return st.assign, st.cur

    def _round(self, moves, feats, B, obj, st, K, warm_steps,
               T_cl, E_cl) -> List[tuple]:
        """Evaluate one round of candidate moves in a single dispatch and
        commit up to ``accept_top`` non-conflicting improving moves.
        Returns the improving-but-unaccepted moves for carry-over."""
        n = len(moves)
        edges = np.array([_move_edges(st.assign, mv) for mv in moves])
        assigns = np.stack([_apply_move(st.assign, mv) for mv in moves])
        Tn, En, tb_n, tf_n = _trials_eval(
            self.sp, feats, assigns, edges, B, warm_steps,
            st.tb[edges], st.tf[edges], pad_to=K)

        # score all K candidate objectives in one vectorised pass
        rows = np.arange(n)[:, None]
        T2 = np.repeat(st.T[None], n, axis=0)
        E2 = np.repeat(st.E[None], n, axis=0)
        T2[rows, edges] = Tn
        E2[rows, edges] = En
        J = np.asarray(obj(T2, E2))

        # accept pass: one jitted sorted/masked scan over the (padded) K
        # candidates instead of a Python loop. Disjoint accepted edges =>
        # disjoint devices => the standalone per-edge solves stay exact
        # under the combined assignment; the scan re-verifies the exact
        # combined objective before each accept. Improving-but-blocked
        # moves come back flagged for carry-over.
        order = np.argsort(J)
        pad = K - n

        def spad(a, fill=0.0):
            a = np.asarray(a)[order]
            return np.concatenate(
                [a, np.full((pad,) + a.shape[1:], fill, a.dtype)]) \
                if pad else a

        T_out, E_out, cur, acc, car = _accept_scan(
            jnp.asarray(spad(J, np.inf)),
            jnp.asarray(spad(edges)),
            jnp.asarray(spad(Tn)), jnp.asarray(spad(En)),
            jnp.asarray(st.T), jnp.asarray(st.E),
            jnp.asarray(st.cur, jnp.float32),
            jnp.asarray(T_cl), jnp.asarray(E_cl),
            jnp.asarray(self.sp.lam, jnp.float32),
            jnp.asarray(np.arange(K) < n),
            accept_top=self.accept_top)
        acc, car = np.asarray(acc), np.asarray(car)

        carry: List[tuple] = []
        for pos in range(n):
            i = order[pos]
            if acc[pos]:
                st.assign = _apply_move(st.assign, moves[i])
                st.tb[edges[i]] = tb_n[i]
                st.tf[edges[i]] = tf_n[i]
            elif car[pos]:
                # improving against the round-start incumbent but its
                # solves are stale (or the accept cap is hit): carry it
                # into the next round's budget instead of discarding
                carry.append(moves[i])
        if acc.any():
            st.T, st.E = np.array(T_out), np.array(E_out)
            st.cur = float(cur)
        return carry
