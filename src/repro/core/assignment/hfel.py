"""HFEL [15] device-assignment search baseline.

Iterative local search over assignment patterns: *transfer* adjustments
(move one device to another edge) and *exchange* adjustments (swap two
devices between edges), each accepted iff it lowers the one-round
objective (17):

    J(Ψ) = Σ_m E_m(Ψ) + λ max_m T_m(Ψ)

where per-edge (T_m, E_m) come from the convex resource allocator
(problem 27) plus the constant cloud terms. The benchmark variants
HFEL-100/HFEL-300 bound the number of exchange trials as in §VI-B.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import resource as ra


def _edge_eval(sp, feats, assign, m, B_m, alloc_steps):
    """Resource-allocate edge m. feats: dict of (H,) arrays; returns
    (T_m, E_m) including cloud constants=0 here (added in total)."""
    mask = jnp.asarray(assign == m)
    res = ra.allocate(sp, feats["u"], feats["D"], feats["p"],
                      feats["g"][:, m], B_m, mask, steps=alloc_steps)
    return float(res.T_edge), float(res.E_edge)


def total_objective(sp: cm.SystemParams, pop: cm.Population, sched_idx,
                    assign, alloc_steps: int = 200
                    ) -> Tuple[float, np.ndarray, np.ndarray]:
    """J(Ψ) for a full assignment; returns (J, T_m array, E_m array)."""
    feats = {"u": pop.u[sched_idx], "D": pop.D[sched_idx],
             "p": pop.p[sched_idx], "g": pop.g[sched_idx]}
    M = pop.n_edges
    T = np.zeros(M)
    E = np.zeros(M)
    for m in range(M):
        T[m], E[m] = _edge_eval(sp, feats, np.asarray(assign), m,
                                float(pop.B_m[m]), alloc_steps)
    T_cl, E_cl = cm.cloud_cost(sp, pop.g_cloud)
    T_m = T + np.asarray(T_cl)
    E_m = E + np.asarray(E_cl)
    return float(E_m.sum() + sp.lam * T_m.max()), T_m, E_m


@dataclasses.dataclass
class HFELAssigner:
    sp: cm.SystemParams
    n_transfer: int = 100
    n_exchange: int = 300
    alloc_steps: int = 200

    def assign(self, pop: cm.Population, sched_idx: np.ndarray,
               rng: np.random.Generator,
               init_assign: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, float]:
        sched_idx = np.asarray(sched_idx)
        H = len(sched_idx)
        M = pop.n_edges
        feats = {"u": pop.u[sched_idx], "D": pop.D[sched_idx],
                 "p": pop.p[sched_idx], "g": pop.g[sched_idx]}
        B = np.asarray(pop.B_m)
        T_cl, E_cl = cm.cloud_cost(self.sp, pop.g_cloud)
        T_cl, E_cl = np.asarray(T_cl), np.asarray(E_cl)

        if init_assign is None:
            assign = np.asarray(np.argmax(np.asarray(pop.g)[sched_idx], axis=1))
        else:
            assign = np.asarray(init_assign).copy()

        # per-edge cached terms
        T = np.zeros(M)
        E = np.zeros(M)
        for m in range(M):
            T[m], E[m] = _edge_eval(self.sp, feats, assign, m, B[m],
                                    self.alloc_steps)

        def obj(Tv, Ev):
            return (Ev + E_cl).sum() + self.sp.lam * (Tv + T_cl).max()

        cur = obj(T, E)

        def try_move(new_assign, edges):
            nonlocal cur, assign, T, E
            T2, E2 = T.copy(), E.copy()
            for m in edges:
                T2[m], E2[m] = _edge_eval(self.sp, feats, new_assign, m,
                                          B[m], self.alloc_steps)
            new = obj(T2, E2)
            if new < cur - 1e-9:
                assign, T, E, cur = new_assign, T2, E2, new
                return True
            return False

        # ---- transfer adjustments
        for _ in range(self.n_transfer):
            h = rng.integers(H)
            src = assign[h]
            dst = rng.integers(M)
            if dst == src:
                continue
            na = assign.copy()
            na[h] = dst
            try_move(na, (src, dst))

        # ---- exchange adjustments
        for _ in range(self.n_exchange):
            h1, h2 = rng.integers(H), rng.integers(H)
            m1, m2 = assign[h1], assign[h2]
            if m1 == m2:
                continue
            na = assign.copy()
            na[h1], na[h2] = m2, m1
            try_move(na, (m1, m2))

        return assign, cur
