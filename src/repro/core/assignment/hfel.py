"""HFEL [15] device-assignment search baseline.

Iterative local search over assignment patterns: *transfer* adjustments
(move one device to another edge) and *exchange* adjustments (swap two
devices between edges), each accepted iff it lowers the one-round
objective (17):

    J(Ψ) = Σ_m E_m(Ψ) + λ max_m T_m(Ψ)

where per-edge (T_m, E_m) come from the convex resource allocator
(problem 27) plus the constant cloud terms. The benchmark variants
HFEL-100/HFEL-300 bound the number of exchange trials as in §VI-B.

All allocator calls go through the batched ``allocate_batch`` solver:
full-pattern evaluations solve all M edges in one vmapped jit call, and
each transfer/exchange trial re-solves its two affected edges in one
call — the search runs thousands of allocations per assignment, so this
is the HFEL hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import resource as ra


def _edges_eval(sp, feats, assign, edges: Sequence[int], B,
                alloc_steps: int) -> Tuple[np.ndarray, np.ndarray]:
    """Resource-allocate a subset of edges in ONE batched jit call.

    feats: dict of (H,)/(H, M) cohort arrays; edges: edge ids to solve.
    Returns (T, E) arrays of shape (len(edges),) excluding cloud
    constants (added by callers)."""
    edges = np.asarray(edges)
    k = len(edges)
    H = feats["u"].shape[0]
    masks = jnp.asarray(np.asarray(assign)[None, :] == edges[:, None])
    res = ra.allocate_batch(
        sp,
        jnp.broadcast_to(feats["u"], (k, H)),
        jnp.broadcast_to(feats["D"], (k, H)),
        jnp.broadcast_to(feats["p"], (k, H)),
        feats["g"][:, edges].T, jnp.asarray(B)[edges], masks,
        steps=alloc_steps)
    return np.asarray(res.T_edge), np.asarray(res.E_edge)


def total_objective(sp: cm.SystemParams, pop: cm.Population, sched_idx,
                    assign, alloc_steps: int = 200
                    ) -> Tuple[float, np.ndarray, np.ndarray]:
    """J(Ψ) for a full assignment; returns (J, T_m array, E_m array)."""
    res = ra.allocate_all_edges(sp, pop, sched_idx, assign,
                                steps=alloc_steps)
    T_cl, E_cl = cm.cloud_cost(sp, pop.g_cloud)
    T_m = np.asarray(res.T_edge) + np.asarray(T_cl)
    E_m = np.asarray(res.E_edge) + np.asarray(E_cl)
    return float(E_m.sum() + sp.lam * T_m.max()), T_m, E_m


@dataclasses.dataclass
class HFELAssigner:
    sp: cm.SystemParams
    n_transfer: int = 100
    n_exchange: int = 300
    alloc_steps: int = 200

    def assign(self, pop: cm.Population, sched_idx: np.ndarray,
               rng: np.random.Generator,
               init_assign: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, float]:
        sched_idx = np.asarray(sched_idx)
        H = len(sched_idx)
        M = pop.n_edges
        feats = {"u": pop.u[sched_idx], "D": pop.D[sched_idx],
                 "p": pop.p[sched_idx], "g": pop.g[sched_idx]}
        B = np.asarray(pop.B_m)
        T_cl, E_cl = cm.cloud_cost(self.sp, pop.g_cloud)
        T_cl, E_cl = np.asarray(T_cl), np.asarray(E_cl)

        if init_assign is None:
            assign = np.asarray(np.argmax(np.asarray(pop.g)[sched_idx], axis=1))
        else:
            assign = np.asarray(init_assign).copy()

        # per-edge cached terms — all M edges in one batched solve
        T, E = _edges_eval(self.sp, feats, assign, np.arange(M), B,
                           self.alloc_steps)

        def obj(Tv, Ev):
            return (Ev + E_cl).sum() + self.sp.lam * (Tv + T_cl).max()

        cur = obj(T, E)

        def try_move(new_assign, edges):
            nonlocal cur, assign, T, E
            T2, E2 = T.copy(), E.copy()
            edges = list(edges)
            T2[edges], E2[edges] = _edges_eval(self.sp, feats, new_assign,
                                               edges, B, self.alloc_steps)
            new = obj(T2, E2)
            if new < cur - 1e-9:
                assign, T, E, cur = new_assign, T2, E2, new
                return True
            return False

        # ---- transfer adjustments
        for _ in range(self.n_transfer):
            h = rng.integers(H)
            src = assign[h]
            dst = rng.integers(M)
            if dst == src:
                continue
            na = assign.copy()
            na[h] = dst
            try_move(na, (src, dst))

        # ---- exchange adjustments
        for _ in range(self.n_exchange):
            h1, h2 = rng.integers(H), rng.integers(H)
            m1, m2 = assign[h1], assign[h2]
            if m1 == m2:
                continue
            na = assign.copy()
            na[h1], na[h2] = m2, m1
            try_move(na, (m1, m2))

        return assign, cur
