"""HFEL [15] device-assignment search baseline.

Iterative local search over assignment patterns: *transfer* adjustments
(move one device to another edge) and *exchange* adjustments (swap two
devices between edges), each accepted iff it lowers the one-round
objective (17):

    J(Ψ) = Σ_m E_m(Ψ) + λ max_m T_m(Ψ)

where per-edge (T_m, E_m) come from the convex resource allocator
(problem 27) plus the constant cloud terms. The benchmark variants
HFEL-100/HFEL-300 bound the number of exchange trials as in §VI-B.

Two search engines share the move neighborhood:

* ``search="serial"`` — the literature-faithful accept/reject loop: one
  trial per step, each re-solving its two affected edges in one small
  ``allocate_batch`` call. Kept as the parity oracle
  (``tests/test_assignment.py`` pins batched quality against it).
* ``search="batched"`` (default) — the K-candidate round engine. Each
  round samples K moves *without replacement* from the current move
  neighborhood, materialises the 2K affected-edge membership masks,
  solves ALL of them in ONE ``allocate_batch`` dispatch (flat
  ``(K·2, H)`` layout via ``resource.flatten_trials`` /
  ``unflatten_trials``), scores all K objectives J(Ψ_k) in one
  vectorised pass, and commits up to ``accept_top`` non-conflicting
  improving moves in ΔJ order — the accept pass itself is a jitted
  sorted/masked ``lax.scan`` (``_accept_scan``), not a Python loop over
  the K candidates. Moves with disjoint affected-edge sets
  also move disjoint devices, so their per-edge solves compose exactly;
  each extra accept is re-verified against the exact combined objective
  before committing. A serial trial budget of n maps onto
  ``ceil(n / n_candidates)`` rounds, so HFEL-100/HFEL-300 keep their
  §VI-B trial counts while paying ~K× fewer jit dispatches — the
  latency gap the source paper (arXiv:2402.02506) holds against search
  baselines.

  Trial edges differ from the incumbent by a single moved device, so
  their re-solves are *warm-started* from the incumbent's per-edge
  solver iterates (``resource.allocate_batch_warm``) at ``warm_steps``
  Adam steps (default 40% of ``alloc_steps``) — cutting solver FLOPs,
  not just dispatch overhead, relative to cold serial trials.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import resource as ra

_TRANSFER, _EXCHANGE = 0, 1


def _objective(Tv, Ev, T_cl, E_cl, lam):
    """J(Ψ) (17) including the constant cloud terms. Works on numpy or
    jnp arrays, and reduces the trailing edge axis so it scores one (M,)
    pattern or a whole (K, M) candidate round. The single authoritative
    formula — shared by the host-side scoring in ``assign`` and the
    jitted accept scan, so the two can never diverge."""
    return (Ev + E_cl).sum(-1) + lam * (Tv + T_cl).max(-1)


@functools.partial(jax.jit, static_argnames=("accept_top",))
def _accept_scan(J, edges, Tn, En, T0, E0, cur0, T_cl, E_cl, lam, valid,
                 *, accept_top: int):
    """Vectorised accept pass over one round's candidates, sorted by J.

    Replaces the host-side Python loop over ≤K moves with ONE jitted
    ``lax.scan`` carrying the incumbent per-edge (T, E) tables, the
    current objective, the set of already-touched edges (an (M,) mask)
    and the accept count. Inputs are ASCENDING-J sorted and padded to a
    fixed K (``valid`` masks the padding), so each (K, M) shape compiles
    once. Per candidate, in order:

    * improving — J beats the ROUND-START incumbent ``cur0`` (the sorted
      serial loop's early ``break``: every later candidate fails too);
    * blocked — an edge already touched by an accepted move, or the
      ``accept_top`` cap: emit a carry flag (re-proposed next round);
    * otherwise re-verify the EXACT combined objective against the
      carried tables and accept iff it beats the carried ``cur``.

    Returns (T, E, cur, accept_flags, carry_flags) — flags in the sorted
    order, committed to host state by the caller.
    """
    M = T0.shape[0]

    def step(carry, inp):
        T, E, cur, used, n_acc = carry
        j_i, e, t_i, e_i, v = inp
        improving = v & (j_i < cur0 - 1e-9)
        blocked = used[e[0]] | used[e[1]] | (n_acc >= accept_top)
        T_try = T.at[e].set(t_i)
        E_try = E.at[e].set(e_i)
        J_try = _objective(T_try, E_try, T_cl, E_cl, lam)
        accept = improving & ~blocked & (J_try < cur - 1e-9)
        T = jnp.where(accept, T_try, T)
        E = jnp.where(accept, E_try, E)
        cur = jnp.where(accept, J_try, cur)
        touched = (jnp.arange(M) == e[0]) | (jnp.arange(M) == e[1])
        used = used | (accept & touched)
        n_acc = n_acc + accept.astype(jnp.int32)
        return (T, E, cur, used, n_acc), (accept, improving & blocked)

    init = (T0, E0, cur0, jnp.zeros(M, bool), jnp.asarray(0, jnp.int32))
    (T, E, cur, _, _), (acc, car) = jax.lax.scan(
        step, init, (J, edges, Tn, En, valid))
    return T, E, cur, acc, car


def _edges_eval_warm(sp, feats, assign, edges, B, steps, tb0, tf0):
    """Resource-allocate a subset of edges in ONE batched jit call.

    feats: dict of (H,)/(H, M) cohort arrays; edges: edge ids to solve;
    tb0/tf0: (len(edges), H) warm-start iterates — neutral (zeros/ones)
    iterates make this numerically the cold solve. Returns (T, E, tb,
    tf): per-edge costs excluding cloud constants (added by callers)
    plus the final iterates so callers can maintain warm-start caches.
    """
    edges = np.asarray(edges)
    k = len(edges)
    H = feats["u"].shape[0]
    masks = jnp.asarray(np.asarray(assign)[None, :] == edges[:, None])
    res, (tb, tf) = ra.allocate_batch_warm(
        sp,
        jnp.broadcast_to(feats["u"], (k, H)),
        jnp.broadcast_to(feats["D"], (k, H)),
        jnp.broadcast_to(feats["p"], (k, H)),
        feats["g"][:, edges].T, jnp.asarray(B)[edges], masks,
        jnp.asarray(tb0), jnp.asarray(tf0), steps=steps)
    return (np.asarray(res.T_edge), np.asarray(res.E_edge),
            np.asarray(tb), np.asarray(tf))


def _edges_eval(sp, feats, assign, edges: Sequence[int], B,
                alloc_steps: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cold ``_edges_eval_warm`` returning just the (T, E) costs — the
    serial oracle's per-trial solve."""
    k = len(np.asarray(edges))
    H = feats["u"].shape[0]
    T, E, _, _ = _edges_eval_warm(sp, feats, assign, edges, B, alloc_steps,
                                  np.zeros((k, H), np.float32),
                                  np.ones((k, H), np.float32))
    return T, E


def _trials_eval(sp, feats, assigns, edges, B, steps: int, tb0, tf0,
                 pad_to: int = 0):
    """Solve the affected edges of K candidate moves in ONE batched call.

    assigns: (K, H) candidate assignment per move; edges: (K, E)
    affected edge ids per move; tb0/tf0: (K, E, H) warm-start iterates
    (the incumbent solutions of the affected edges — each trial differs
    from its incumbent by one moved device, so ``steps`` can be a
    fraction of the cold-start count). Builds the (K, E, H) membership
    masks, flattens everything to ``allocate_batch``'s flat (K·E, H)
    trial layout, and unflattens the result back to move-major arrays.
    ``pad_to > K`` pads the trial axis by repeating rows so every round
    reuses one compiled (pad_to·E, H) program regardless of how many
    proposals survived validity filtering.

    Returns (T, E, tb, tf): (K, E) costs excluding cloud constants plus
    the (K, E, H) final iterates for cache maintenance on accept.
    """
    assigns = np.asarray(assigns)
    edges = np.asarray(edges)
    tb0, tf0 = np.asarray(tb0), np.asarray(tf0)
    k = edges.shape[0]
    if pad_to > k:
        pad = pad_to - k
        rep = lambda a: np.concatenate([a, np.repeat(a[:1], pad, 0)])  # noqa: E731
        assigns, edges, tb0, tf0 = map(rep, (assigns, edges, tb0, tf0))
    K, n_aff = edges.shape
    H = assigns.shape[1]
    masks = jnp.asarray(assigns[:, None, :] == edges[:, :, None])
    g = jnp.asarray(feats["g"]).T[jnp.asarray(edges)]          # (K, E, H)
    u = jnp.broadcast_to(feats["u"], (K, n_aff, H))
    D = jnp.broadcast_to(feats["D"], (K, n_aff, H))
    p = jnp.broadcast_to(feats["p"], (K, n_aff, H))
    B_k = jnp.asarray(np.asarray(B)[edges])                    # (K, E)
    flat = ra.flatten_trials(u, D, p, g, B_k, masks, tb0, tf0)
    res, (tb, tf) = ra.allocate_batch_warm(sp, *flat, steps=steps)
    res = ra.unflatten_trials(res, K, n_aff)
    unflat = lambda a: np.asarray(a).reshape(K, n_aff, H)[:k]  # noqa: E731
    return (np.asarray(res.T_edge)[:k], np.asarray(res.E_edge)[:k],
            unflat(tb), unflat(tf))


def total_objective(sp: cm.SystemParams, pop: cm.Population, sched_idx,
                    assign, alloc_steps: int = 200
                    ) -> Tuple[float, np.ndarray, np.ndarray]:
    """J(Ψ) for a full assignment; returns (J, T_m array, E_m array)."""
    res = ra.allocate_all_edges(sp, pop, sched_idx, assign,
                                steps=alloc_steps)
    T_cl, E_cl = cm.cloud_cost(sp, pop.g_cloud)
    T_m = np.asarray(res.T_edge) + np.asarray(T_cl)
    E_m = np.asarray(res.E_edge) + np.asarray(E_cl)
    return float(E_m.sum() + sp.lam * T_m.max()), T_m, E_m


def _apply_move(assign: np.ndarray, move) -> np.ndarray:
    """New assignment after one transfer/exchange move (copy)."""
    kind, x, y = move
    na = assign.copy()
    if kind == _TRANSFER:
        na[x] = y
    else:
        na[x], na[y] = assign[y], assign[x]
    return na


def _move_edges(assign: np.ndarray, move) -> Tuple[int, int]:
    """The two edges whose membership a move changes."""
    kind, x, y = move
    return (int(assign[x]), int(y)) if kind == _TRANSFER else \
        (int(assign[x]), int(assign[y]))


@dataclasses.dataclass
class _BatchedState:
    """Incumbent of the batched search: assignment, per-edge (T, E)
    caches, and the per-edge solver iterates seeding warm re-solves."""
    assign: np.ndarray   # (H,) current edge per scheduled device
    T: np.ndarray        # (M,) cached per-edge delays
    E: np.ndarray        # (M,) cached per-edge energies
    tb: np.ndarray       # (M, H) bandwidth-logit iterates
    tf: np.ndarray       # (M, H) frequency iterates
    cur: float = np.inf  # objective J of the incumbent


@dataclasses.dataclass
class HFELAssigner:
    sp: cm.SystemParams
    n_transfer: int = 100
    n_exchange: int = 300
    alloc_steps: int = 200
    search: str = "batched"        # "batched" | "serial" (oracle)
    n_candidates: int = 16         # K: trials per batched round
    accept_top: int = 4            # max non-conflicting accepts per round
    warm_steps: Optional[int] = None   # trial re-solve steps (None: 40%)

    def assign(self, pop: cm.Population, sched_idx: np.ndarray,
               rng: np.random.Generator,
               init_assign: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, float]:
        if self.search not in ("batched", "serial"):
            raise ValueError(f"unknown HFEL search engine: {self.search!r}")
        sched_idx = np.asarray(sched_idx)
        H = len(sched_idx)
        M = pop.n_edges
        feats = {"u": pop.u[sched_idx], "D": pop.D[sched_idx],
                 "p": pop.p[sched_idx], "g": pop.g[sched_idx]}
        B = np.asarray(pop.B_m)
        T_cl, E_cl = cm.cloud_cost(self.sp, pop.g_cloud)
        T_cl, E_cl = np.asarray(T_cl), np.asarray(E_cl)

        if init_assign is None:
            assign = np.asarray(np.argmax(np.asarray(pop.g)[sched_idx], axis=1))
        else:
            assign = np.asarray(init_assign).copy()

        obj = functools.partial(_objective, T_cl=T_cl, E_cl=E_cl,
                                lam=self.sp.lam)

        if self.search == "serial":
            return self._search_serial(feats, B, obj, assign, rng, H, M)
        return self._search_batched(feats, B, obj, assign, rng, H, M,
                                    T_cl, E_cl)

    # ------------------------------------------------------ serial oracle

    def _search_serial(self, feats, B, obj, assign, rng, H, M):
        """One-trial-at-a-time accept/reject loop (original HFEL)."""
        # per-edge cached terms — all M edges in one batched solve
        T, E = _edges_eval(self.sp, feats, assign, np.arange(M), B,
                           self.alloc_steps)
        cur = float(obj(T, E))

        def try_move(new_assign, edges):
            nonlocal cur, assign, T, E
            T2, E2 = T.copy(), E.copy()
            edges = list(edges)
            T2[edges], E2[edges] = _edges_eval(self.sp, feats, new_assign,
                                               edges, B, self.alloc_steps)
            new = float(obj(T2, E2))
            if new < cur - 1e-9:
                assign, T, E, cur = new_assign, T2, E2, new
                return True
            return False

        # ---- transfer adjustments
        for _ in range(self.n_transfer):
            h = rng.integers(H)
            src = assign[h]
            dst = rng.integers(M)
            if dst == src:
                continue
            na = assign.copy()
            na[h] = dst
            try_move(na, (src, dst))

        # ---- exchange adjustments
        for _ in range(self.n_exchange):
            h1, h2 = rng.integers(H), rng.integers(H)
            m1, m2 = assign[h1], assign[h2]
            if m1 == m2:
                continue
            na = assign.copy()
            na[h1], na[h2] = m2, m1
            try_move(na, (m1, m2))

        return assign, cur

    # -------------------------------------------------- batched K-rounds

    def _propose(self, rng, assign, H, M, k, kind,
                 carry: List[tuple]) -> List[tuple]:
        """Assemble one round of k trial moves: carried-over moves first
        (improving last round but conflicting with an accepted move —
        still promising, so they spend this round's budget ahead of
        fresh draws), topped up with fresh proposals sampled without
        replacement from the move neighborhood of ``assign``.

        Like the serial loop, invalid draws (self-transfer, same-edge
        exchange) consume trial budget without an allocator call, so a
        budget of n means n raw trials under either engine.
        """
        moves = [mv for mv in carry
                 if _move_edges(assign, mv)[0] != _move_edges(assign, mv)[1]
                 ][:k]
        seen = {mv[1:] if mv[0] == _EXCHANGE else mv for mv in moves}
        fresh = k - len(moves)
        if fresh <= 0:
            return moves
        if kind == _TRANSFER:                      # (device h, dest edge)
            raw = rng.choice(H * M, size=min(fresh, H * M), replace=False)
            h, dst = raw // M, raw % M
            ok = assign[h] != dst
            for a, b in zip(h[ok], dst[ok]):
                mv = (_TRANSFER, int(a), int(b))
                if mv not in seen:
                    seen.add(mv)
                    moves.append(mv)
            return moves
        # exchange: ordered (h1, h2) like the serial draws, then
        # canonicalised so a round never evaluates the same swap twice
        raw = rng.choice(H * H, size=min(fresh, H * H), replace=False)
        h1, h2 = raw // H, raw % H
        ok = (h1 != h2) & (assign[h1] != assign[h2])
        for a, b in zip(h1[ok], h2[ok]):
            key = (int(min(a, b)), int(max(a, b)))
            if key not in seen:
                seen.add(key)
                moves.append((_EXCHANGE, key[0], key[1]))
        return moves

    def _search_batched(self, feats, B, obj, assign, rng, H, M, T_cl, E_cl):
        K = max(1, int(self.n_candidates))
        warm = self.warm_steps or max(25, (2 * self.alloc_steps) // 5)
        # all M edges in one full-fidelity solve; neutral iterates make
        # it the cold solve, and its final iterates seed the warm caches
        T0, E0, tb0, tf0 = _edges_eval_warm(
            self.sp, feats, assign, np.arange(M), B, self.alloc_steps,
            np.zeros((M, H), np.float32), np.ones((M, H), np.float32))
        # np.array: jax buffers are read-only views; caches are written
        st = _BatchedState(assign, T0, E0, np.array(tb0), np.array(tf0))
        st.cur = float(obj(st.T, st.E))
        for kind, budget in ((_TRANSFER, self.n_transfer),
                             (_EXCHANGE, self.n_exchange)):
            remaining = int(budget)
            carry: List[tuple] = []
            while remaining > 0:
                k = min(K, remaining)
                remaining -= k
                moves = self._propose(rng, st.assign, H, M, k, kind, carry)
                if moves:
                    carry = self._round(moves, feats, B, obj, st, K, warm,
                                        T_cl, E_cl)
        return st.assign, st.cur

    def _round(self, moves, feats, B, obj, st, K, warm_steps,
               T_cl, E_cl) -> List[tuple]:
        """Evaluate one round of candidate moves in a single dispatch and
        commit up to ``accept_top`` non-conflicting improving moves.
        Returns the improving-but-unaccepted moves for carry-over."""
        n = len(moves)
        edges = np.array([_move_edges(st.assign, mv) for mv in moves])
        assigns = np.stack([_apply_move(st.assign, mv) for mv in moves])
        Tn, En, tb_n, tf_n = _trials_eval(
            self.sp, feats, assigns, edges, B, warm_steps,
            st.tb[edges], st.tf[edges], pad_to=K)

        # score all K candidate objectives in one vectorised pass
        rows = np.arange(n)[:, None]
        T2 = np.repeat(st.T[None], n, axis=0)
        E2 = np.repeat(st.E[None], n, axis=0)
        T2[rows, edges] = Tn
        E2[rows, edges] = En
        J = np.asarray(obj(T2, E2))

        # accept pass: one jitted sorted/masked scan over the (padded) K
        # candidates instead of a Python loop. Disjoint accepted edges =>
        # disjoint devices => the standalone per-edge solves stay exact
        # under the combined assignment; the scan re-verifies the exact
        # combined objective before each accept. Improving-but-blocked
        # moves come back flagged for carry-over.
        order = np.argsort(J)
        pad = K - n

        def spad(a, fill=0.0):
            a = np.asarray(a)[order]
            return np.concatenate(
                [a, np.full((pad,) + a.shape[1:], fill, a.dtype)]) \
                if pad else a

        T_out, E_out, cur, acc, car = _accept_scan(
            jnp.asarray(spad(J, np.inf)),
            jnp.asarray(spad(edges)),
            jnp.asarray(spad(Tn)), jnp.asarray(spad(En)),
            jnp.asarray(st.T), jnp.asarray(st.E),
            jnp.asarray(st.cur, jnp.float32),
            jnp.asarray(T_cl), jnp.asarray(E_cl),
            jnp.asarray(self.sp.lam, jnp.float32),
            jnp.asarray(np.arange(K) < n),
            accept_top=self.accept_top)
        acc, car = np.asarray(acc), np.asarray(car)

        carry: List[tuple] = []
        for pos in range(n):
            i = order[pos]
            if acc[pos]:
                st.assign = _apply_move(st.assign, moves[i])
                st.tb[edges[i]] = tb_n[i]
                st.tf[edges[i]] = tf_n[i]
            elif car[pos]:
                # improving against the round-start incumbent but its
                # solves are stale (or the accept cap is hit): carry it
                # into the next round's budget instead of discarding
                carry.append(moves[i])
        if acc.any():
            st.T, st.E = np.array(T_out), np.array(E_out)
            st.cur = float(cur)
        return carry
