"""Deployment wrapper: assign devices with a trained D3QN agent (greedy)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.drl.d3qn import q_values_all_t


@dataclasses.dataclass
class DRLAssigner:
    sp: cm.SystemParams
    params: dict                   # trained D3QN parameters

    def __post_init__(self):
        self._q = jax.jit(q_values_all_t)

    def assign(self, pop: cm.Population, sched_idx,
               rng=None) -> Tuple[np.ndarray, None]:
        from repro.drl.train import drl_features
        feats = drl_features(pop, sched_idx)
        q = np.asarray(self._q(self.params, jnp.asarray(feats)))
        return q.argmax(axis=-1), None
