"""Deployment wrapper: assign devices with a trained D3QN agent (greedy).

Q evaluation goes through the module-level jitted entry points in
``repro.drl.d3qn`` (shared with the trainer), so every ``DRLAssigner``
instance reuses one compiled program per input shape instead of
re-jitting per instance. ``assign_batch`` is the multi-population path:
E populations' greedy assignments in ONE dispatch (the fig6 benchmark
and multi-lane sweeps ride it).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.drl.d3qn import (q_values_all_t, q_values_all_t_jit,
                            q_values_batch_jit)


def drl_features_traced(u, D, p, g, sched_idx):
    """Traced twin of ``repro.drl.train.drl_features``: gather the
    scheduled cohort from full-population feature columns, convert gains
    to dB and min-max normalise per eq. (24) — all in jnp ops so the
    fused sweep scan can deploy the agent in-trace. u/D/p (N,), g (N, M),
    sched_idx (H,) -> (H, M+3) f32 features. Matches the host path's
    ``Population.features()`` column order (g | u | D | p); arithmetic
    runs in f32 on device vs the host's f64 (sub-ulp differences only
    matter on exact Q-value ties)."""
    feats = jnp.concatenate(
        [g, u[:, None], D[:, None], p[:, None]], axis=1)[sched_idx]
    M = g.shape[1]
    gains_db = 10.0 * jnp.log10(jnp.maximum(feats[:, :M], 1e-30))
    feats = jnp.concatenate([gains_db, feats[:, M:]], axis=1)
    lo = feats.min(axis=-2, keepdims=True)
    hi = feats.max(axis=-2, keepdims=True)
    return ((feats - lo) / jnp.maximum(hi - lo, 1e-12)).astype(jnp.float32)


def drl_assign_traced(params, u, D, p, g, sched_idx):
    """Traced twin of ``DRLAssigner.assign``: greedy (argmax-Q) edge per
    scheduled device through the pure ``q_values_all_t`` trunk, so the
    whole deployment — feature build, BiLSTM encode, dueling heads,
    argmax — stays inside the caller's trace with no host round-trip.
    Returns (H,) int32 edge ids."""
    feats = drl_features_traced(u, D, p, g, sched_idx)
    q = q_values_all_t(params, feats)
    return jnp.argmax(q, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class DRLAssigner:
    sp: cm.SystemParams
    params: dict                   # trained D3QN parameters

    def assign(self, pop: cm.Population, sched_idx,
               rng=None) -> Tuple[np.ndarray, None]:
        from repro.drl.train import drl_features
        feats = drl_features(pop, sched_idx)
        q = np.asarray(q_values_all_t_jit(self.params, jnp.asarray(feats)))
        return q.argmax(axis=-1), None

    def assign_batch(self, pops, sched_idx=None,
                     rng=None) -> Tuple[np.ndarray, None]:
        """Greedy assignments for E populations in one dispatch.

        pops: a ``cost_model.PopulationBatch`` or a sequence of
        same-shape ``Population``s; sched_idx: shared (H,) indices,
        per-population (E, H), or None for all devices. Returns
        ((E, H) edge ids, None) — row e equals ``assign(pops[e], ...)``.
        """
        from repro.drl.train import drl_features_batch
        popb = (pops if isinstance(pops, cm.PopulationBatch)
                else cm.PopulationBatch.stack(pops))
        feats = drl_features_batch(popb, sched_idx)
        q = np.asarray(q_values_batch_jit(self.params, jnp.asarray(feats)))
        return q.argmax(axis=-1), None
