"""Deployment wrapper: assign devices with a trained D3QN agent (greedy).

Q evaluation goes through the module-level jitted entry points in
``repro.drl.d3qn`` (shared with the trainer), so every ``DRLAssigner``
instance reuses one compiled program per input shape instead of
re-jitting per instance. ``assign_batch`` is the multi-population path:
E populations' greedy assignments in ONE dispatch (the fig6 benchmark
and multi-lane sweeps ride it).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.drl.d3qn import q_values_all_t_jit, q_values_batch_jit


@dataclasses.dataclass
class DRLAssigner:
    sp: cm.SystemParams
    params: dict                   # trained D3QN parameters

    def assign(self, pop: cm.Population, sched_idx,
               rng=None) -> Tuple[np.ndarray, None]:
        from repro.drl.train import drl_features
        feats = drl_features(pop, sched_idx)
        q = np.asarray(q_values_all_t_jit(self.params, jnp.asarray(feats)))
        return q.argmax(axis=-1), None

    def assign_batch(self, pops, sched_idx=None,
                     rng=None) -> Tuple[np.ndarray, None]:
        """Greedy assignments for E populations in one dispatch.

        pops: a ``cost_model.PopulationBatch`` or a sequence of
        same-shape ``Population``s; sched_idx: shared (H,) indices,
        per-population (E, H), or None for all devices. Returns
        ((E, H) edge ids, None) — row e equals ``assign(pops[e], ...)``.
        """
        from repro.drl.train import drl_features_batch
        popb = (pops if isinstance(pops, cm.PopulationBatch)
                else cm.PopulationBatch.stack(pops))
        feats = drl_features_batch(popb, sched_idx)
        q = np.asarray(q_values_batch_jit(self.params, jnp.asarray(feats)))
        return q.argmax(axis=-1), None
