# The paper's primary contribution: HFL device scheduling (IKC/VKC),
# DRL-based device assignment (D3QN), convex resource allocation, and the
# joint cost model — composed into the Algorithm-6 framework.
from repro.core import cost_model, resource, clustering, hfl  # noqa: F401
from repro.core.framework import HFLFramework, FrameworkConfig  # noqa: F401
