"""Communication compression for uplink model updates — codecs + error
feedback, charged end-to-end in the cost model.

Every engine up to PR 8 ships full-precision ``model_bits`` payloads on
every uplink. This module provides a family of jit-compatible *update
codecs* applied to parameter deltas against the reference model the
sender pulled (device→edge: the edge model at dispatch; edge→cloud: the
global model):

* ``none``       — identity; the parity oracle. Engines statically
                   short-circuit to their exact uncompressed code path,
                   so ``codec="none"`` reproduces it bitwise.
* ``bf16_delta`` — casts the delta to bfloat16 (16 bits/param).
* ``int8``       — stochastic-rounding quantization to int8 with one
                   per-tensor (per message, per leaf) f32 scale
                   ``max|x|/127``; unbiased: E[decode(encode(x))] = x.
* ``topk``       — magnitude top-k sparsification per leaf
                   (k = max(1, round(topk_frac·n))), sent as
                   (index, value) pairs.

Each codec carries an **error-feedback residual** per sender (Seide et
al. 2014 / Karimireddy et al. 2019): the encoder compresses
``x = delta + residual`` and keeps ``residual' = x - decode(encode(x))``
for the next round, so the *accumulated* compression error stays bounded
and compressed training remains unbiased over rounds (property-tested in
``tests/test_compression.py``).

The compressed per-message size (:func:`message_bits`) is what the cost
model charges: engines patch ``SystemParams.model_bits`` with it, so
``t_com``/``e_com``/``cloud_cost`` (eqs. (7)-(8), (11)-(12)) and the
convex resource allocation all see the codec's actual bits-per-message.

Encoding is row-wise: leaves carry a leading message axis (H devices or
M edges) and every row is one message. ``encode_rows``/``decode_rows``
are the single source of codec math; :func:`encode_decode` composes them
over pytrees, and the kernel aggregation path consumes
``encode_leaf``'s (q, scale) form directly (``kernels/hier_agg``
``masked_decode_aggregate`` folds the scales into the in-kernel weight
panel, so the dense decoded update matrix is never a matmul input).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

CODECS = ("none", "bf16_delta", "int8", "topk")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Uplink update-codec knobs (hashable — used as a static jit arg).

    ``codec="none"`` is the identity oracle: engines skip the delta
    transform entirely and trace their uncompressed program.
    ``error_feedback`` keeps a per-sender residual accumulator across
    rounds; ``seed`` feeds the stochastic-rounding key stream (derived
    per (lane, round), never carried — host-loop and fused-scan engines
    draw identical keys).
    """
    codec: str = "none"             # none | bf16_delta | int8 | topk
    topk_frac: float = 0.05         # fraction of entries kept per leaf
    error_feedback: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; "
                             f"valid: {CODECS}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], "
                             f"got {self.topk_frac}")

    @property
    def active(self) -> bool:
        return self.codec != "none"


def _topk_k(cfg: CompressionConfig, n: int) -> int:
    return min(n, max(1, int(round(cfg.topk_frac * n))))


def message_bits(cfg: CompressionConfig, params) -> float:
    """Bits per uplink message for one model shaped like ``params``.

    ``none`` counts raw parameter bytes; ``int8`` adds one f32 scale per
    leaf; ``topk`` charges (value + index) per kept entry, indices at
    ceil(log2(n)) bits.
    """
    leaves = jax.tree.leaves(params)
    if cfg.codec == "none":
        return float(sum(leaf.size * leaf.dtype.itemsize * 8
                         for leaf in leaves))
    if cfg.codec == "bf16_delta":
        return float(sum(leaf.size * 16 for leaf in leaves))
    if cfg.codec == "int8":
        return float(sum(leaf.size * 8 + 32 for leaf in leaves))
    # topk: (f32 value, index) pairs per leaf
    bits = 0.0
    for leaf in leaves:
        n = leaf.size
        bits += _topk_k(cfg, n) * (32 + max(1, math.ceil(math.log2(n))))
    return float(bits)


def init_state(cfg: CompressionConfig, params, n_rows: int):
    """Zero error-feedback residuals: one per sender row, f32, shaped
    like ``params`` with a leading ``(n_rows,)`` axis. Returns None for
    the identity codec (no state to carry)."""
    if not cfg.active:
        return None
    return jax.tree.map(
        lambda p: jnp.zeros((n_rows,) + p.shape, jnp.float32), params)


# ------------------------------------------------------- row-wise codecs

def encode_rows(cfg: CompressionConfig, key, x):
    """Encode (R, p) f32 rows — R messages of one p-element tensor.

    Returns ``(q, scale)``: the wire form. q is (R, p) int8 (``int8``),
    bf16 (``bf16_delta``) or dense-masked f32 (``topk``, the simulated
    form of the (index, value) pairs); scale is (R,) f32 per-message
    decode scales (ones where the codec has none).
    """
    R = x.shape[0]
    ones = jnp.ones((R,), jnp.float32)
    if cfg.codec == "bf16_delta":
        return x.astype(jnp.bfloat16), ones
    if cfg.codec == "int8":
        absmax = jnp.max(jnp.abs(x), axis=1)
        scale = jnp.maximum(absmax / 127.0, 1e-30)
        u = jax.random.uniform(key, x.shape)
        q = jnp.clip(jnp.floor(x / scale[:, None] + u), -127, 127)
        return q.astype(jnp.int8), scale
    if cfg.codec == "topk":
        k = _topk_k(cfg, x.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(x), k)                  # (R, k)
        keep = jnp.zeros_like(x).at[jnp.arange(R)[:, None], idx].set(1.0)
        return x * keep, ones
    raise ValueError(f"encode_rows on codec {cfg.codec!r}")


def decode_rows(cfg: CompressionConfig, q, scale):
    """Decode the wire form back to (R, p) f32 rows."""
    return q.astype(jnp.float32) * scale[:, None]


def encode_leaf(cfg: CompressionConfig, key, delta, resid):
    """Error-feedback encode of one leaf: (R, p) delta + residual.

    Returns ``(q, scale, new_resid)`` — the wire form plus the updated
    residual ``x - decode(q, scale)`` (pass-through when
    ``error_feedback=False``).
    """
    x = delta + resid if cfg.error_feedback else delta
    q, scale = encode_rows(cfg, key, x)
    if cfg.error_feedback:
        resid = x - decode_rows(cfg, q, scale)
    return q, scale, resid


def encode_decode(cfg: CompressionConfig, key, delta, resid):
    """Compress-then-decompress a pytree of updates with error feedback.

    ``delta``/``resid``: pytrees whose leaves carry a leading message
    axis (R, ...). Returns ``(decoded, new_resid)`` with the same
    structure; the identity codec passes both through untouched.
    """
    if not cfg.active:
        return delta, resid
    d_leaves, treedef = jax.tree.flatten(delta)
    r_leaves = jax.tree.leaves(resid)
    keys = jax.random.split(key, len(d_leaves))
    dec_leaves, new_r = [], []
    for d, r, k in zip(d_leaves, r_leaves, keys):
        R = d.shape[0]
        q, s, nr = encode_leaf(cfg, k, d.reshape(R, -1).astype(jnp.float32),
                               r.reshape(R, -1))
        dec_leaves.append(decode_rows(cfg, q, s).reshape(d.shape))
        new_r.append(nr.reshape(r.shape))
    return treedef.unflatten(dec_leaves), treedef.unflatten(new_r)


def round_key(cfg: CompressionConfig, lane_seed: int, round_idx):
    """Deterministic per-(lane, round) codec key — stateless, so the
    host-loop and fused-scan engines draw identical randomness without
    threading a key through their carries."""
    base = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), lane_seed)
    return jax.random.fold_in(base, round_idx)
