"""Batched simulation sweeps over the fused round engine.

The paper's headline experiments (Figs. 3-7, Table II) are grids of
(scheduler x assigner x scheduling ratio x seed) cells, each a full
multi-round HFL simulation. Re-running ``HFLFramework`` per cell pays the
Python/dispatch overhead S times per round; ``SweepRunner`` instead
stacks S independent worlds (population + federated data) along a
leading lane axis and vmaps the traceable ``round_step_core`` over it
(``_sweep_round_lanes``), so every round of every lane is ONE jitted
dispatch. Scheduling ratios change the cohort shape H, so each ratio is
its own vmapped program (lanes within a ratio share one).

Three further dispatch layouts compose on top of the per-round vmap
(details in ``docs/engine.md``): ``shard=True`` block-shards the lane
axis over a 1-D device mesh via ``shard_map`` (``sweep_round_sharded``),
``lane_chunk=k`` executes lanes in sequential vmapped chunks (CPU
cache-blocking), and ``run(fused=True)`` folds the entire R-round sweep
— scheduling, assignment, eval and done-masks traced — into one
``lax.scan`` dispatch (``sweep_scan`` / ``sweep_scan_sharded``).

Semantics per lane match ``HFLFramework`` with ``engine="fused"``:
Algorithm-1 training weighted by the cost-model dataset sizes pop.D,
all-edges convex resource allocation, and round costs (13)/(14).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.core import compression as comp
from repro.core import cost_model as cm
from repro.core.assignment.drl import drl_assign_traced
from repro.core.assignment.geo import GeoAssigner, geo_assign_traced
from repro.core.assignment.hfel import hfel_search_traced
from repro.core.framework import round_step_core
from repro.core.hfl import hfl_global_iteration_core, pad_device_data
from repro.core.scheduling import (FedAvgScheduler, IKCScheduler,
                                   VKCScheduler, run_device_clustering)
from repro.core.scheduling.schedulers import TracedFedAvg, _topup
from repro.configs.registry import get_hfl_spec
from repro.data.partition import FederatedData
from repro.utils import tree_bytes


def build_scheduler(name: str, fed: FederatedData, sp: cm.SystemParams,
                    H: int, K: int = 10, lr: float = 0.01, seed: int = 0,
                    use_kernel: bool = False,
                    pop: Optional[cm.Population] = None,
                    arch: str = "hfl-cnn"):
    """Standalone scheduler construction (shared by benchmarks/sweeps).

    IKC clusters with the arch's auxiliary mini model ξ on its
    clustering crop, VKC with the full payload, FedAvg samples
    uniformly — mirroring ``HFLFramework._setup_scheduler`` without
    instantiating the whole framework. NOTE: the framework keeps its own
    copy because its key derivation and clustering-cost/ARI bookkeeping
    are part of its seeded record; if the clustering recipe changes,
    update BOTH. Both the full and the mini init take ``fed.n_classes``
    (an earlier revision silently defaulted to 10, mispricing
    ``compute_scale`` and clustering with the wrong logits head whenever
    ``n_classes != 10``).

    With ``pop`` given, returns (scheduler, clustering_stats) where
    clustering_stats carries the Table-II quantities (ari, delay_s,
    energy_j, aux_bits; empty dict for FedAvg); otherwise returns just
    the scheduler.
    """
    from repro.core.clustering import adjusted_rand_index
    from repro.core.scheduling.device_clustering import clustering_cost
    from repro.utils import tree_bytes as _tb

    if name == "fedavg":
        sched = FedAvgScheduler(fed.n_devices, H)
        return (sched, {}) if pop is not None else sched
    if name not in ("ikc", "vkc"):
        raise ValueError(f"unknown scheduler {name!r}")
    spec = get_hfl_spec(arch)
    key = jax.random.PRNGKey(seed)
    X, y, mask = pad_device_data(fed)
    h = max(1, H // K)
    full = spec.init_fn(key, fed)
    full_bits = _tb(full) * 8
    if name == "ikc":
        mini = spec.mini_init_fn(key, fed)
        crop = spec.mini_preprocess_fn(X, key)
        labels, _ = run_device_clustering(key, spec.mini_apply_fn, mini,
                                          crop, y, mask, K, sp.L, lr,
                                          use_kernel=use_kernel)
        sched = IKCScheduler(labels, h)
        aux_bits = _tb(mini) * 8
        compute_scale = aux_bits / max(1, full_bits)
    else:
        labels, _ = run_device_clustering(key, spec.apply_fn, full, X, y,
                                          mask, K, sp.L, lr,
                                          use_kernel=use_kernel)
        sched = VKCScheduler(labels, h)
        aux_bits = full_bits
        compute_scale = 1.0
    if pop is None:
        return sched
    delay, energy = clustering_cost(sp, pop, aux_bits,
                                    compute_scale=compute_scale)
    stats = {"ari": adjusted_rand_index(np.asarray(labels),
                                        fed.majority_class),
             "delay_s": delay, "energy_j": energy,
             "aux_bits": float(aux_bits)}
    return sched, stats


def _sweep_round_lanes(apply_fn, sp: cm.SystemParams, params_b, u_b, D_b,
                       p_b, g_b, g_cloud_b, B_m_b, X_b, y_b, mask_b,
                       sizes_b, sched_b, assign_b, lr, done_b, *, M: int,
                       L: int, Q: int, alloc_steps: int, train_only: bool,
                       agg_kernel: bool, lane_chunk: Optional[int] = None,
                       codec=None, codec_state_b=None, codec_keys_b=None):
    """Traceable lane-vmapped round body shared by the single-device
    ``sweep_round`` jit and the ``shard_map`` blocks of
    ``sweep_round_sharded`` (each device runs this on its lane block).

    lane_chunk: None vmaps the whole lane axis into one batched program
    (the PR-1 layout, right for MXU-rich hardware). An int processes the
    lanes sequentially in vmapped chunks of that size via ``lax.map`` —
    on CPU hosts the small per-chunk working set stays cache-resident
    and XLA stops batch-fusing the tiny per-lane ops into bandwidth-
    bound monsters, which measures 1.8-2.4x by itself at S=128 across
    runs (see ``BENCH_sweep_shard.json``); must divide the lane-axis
    length.

    With an active ``codec`` the compressed round engine runs instead:
    ``codec_state_b`` is ``(dev_resid (S, N, ...), edge_resid
    (S, M, ...))`` error-feedback trees (cohort rows gathered/scattered
    per lane, frozen on done lanes like the params), ``codec_keys_b``
    (S, 2) per-lane round keys, and the return gains a third element —
    the updated state. Inactive codec keeps the seed trace untouched.
    """
    codec_on = codec is not None and codec.active

    def one(params, u, D, p, g, g_cloud, B_m, X, y, mask, sizes, sched,
            assign, done, *cstate):
        if codec_on:
            dev_resid, edge_resid, ckey = cstate
            cohort_resid = jax.tree.map(lambda r: r[sched], dev_resid)
        if train_only:
            if codec_on:
                new_params, cohort_resid, new_edge_resid = \
                    hfl_global_iteration_core(
                        apply_fn, params, X[sched], y[sched], mask[sched],
                        sizes[sched], assign, M=M, L=L, Q=Q, lr=lr,
                        agg_kernel=agg_kernel, codec=codec,
                        dev_resid=cohort_resid, edge_resid=edge_resid,
                        codec_key=ckey)
            else:
                new_params = hfl_global_iteration_core(
                    apply_fn, params, X[sched], y[sched], mask[sched],
                    sizes[sched], assign, M=M, L=L, Q=Q, lr=lr,
                    agg_kernel=agg_kernel)
            zero = jnp.zeros(())
            T_i, E_i = zero, zero
        elif codec_on:
            new_params, (cohort_resid, new_edge_resid), \
                (T_i, E_i, _, _, _, _) = round_step_core(
                    apply_fn, sp, params, u[sched], D[sched], p[sched],
                    g[sched], g_cloud, B_m, X[sched], y[sched],
                    mask[sched], sizes[sched], assign, lr, M=M, L=L, Q=Q,
                    alloc_steps=alloc_steps, agg_kernel=agg_kernel,
                    codec=codec, codec_state=(cohort_resid, edge_resid),
                    codec_key=ckey)
        else:
            new_params, (T_i, E_i, _, _, _, _) = round_step_core(
                apply_fn, sp, params, u[sched], D[sched], p[sched],
                g[sched], g_cloud, B_m, X[sched], y[sched], mask[sched],
                sizes[sched], assign, lr, M=M, L=L, Q=Q,
                alloc_steps=alloc_steps, agg_kernel=agg_kernel)
        new_params = jax.tree.map(
            lambda old, new: jnp.where(done, old, new), params, new_params)
        costs = (jnp.where(done, 0.0, T_i), jnp.where(done, 0.0, E_i))
        if not codec_on:
            return new_params, costs
        freeze = functools.partial(
            jax.tree.map, lambda old, new: jnp.where(done, old, new))
        new_dev_resid = freeze(
            dev_resid, jax.tree.map(
                lambda full, nr: full.at[sched].set(nr), dev_resid,
                cohort_resid))
        return new_params, costs, (new_dev_resid,
                                   freeze(edge_resid, new_edge_resid))

    lane_in = (params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b, y_b,
               mask_b, sizes_b, sched_b, assign_b, done_b)
    if codec_on:
        lane_in = lane_in + (codec_state_b[0], codec_state_b[1],
                             codec_keys_b)
    if lane_chunk is None:
        return jax.vmap(one)(*lane_in)
    n = sched_b.shape[0]
    if n % lane_chunk != 0:
        raise ValueError(f"lane_chunk={lane_chunk} must divide the lane "
                         f"axis ({n})")
    stacked = jax.tree.map(
        lambda x: x.reshape((n // lane_chunk, lane_chunk) + x.shape[1:]),
        lane_in)
    out = jax.lax.map(lambda xs: jax.vmap(one)(*xs), stacked)
    return jax.tree.map(
        lambda x: x.reshape((n,) + x.shape[2:]), out)


@functools.partial(jax.jit, static_argnames=(
    "apply_fn", "sp", "M", "L", "Q", "alloc_steps", "train_only",
    "agg_kernel", "lane_chunk", "codec"))
def sweep_round(apply_fn, sp: cm.SystemParams, params_b, u_b, D_b, p_b,
                g_b, g_cloud_b, B_m_b, X_b, y_b, mask_b, sizes_b, sched_b,
                assign_b, lr, *, M: int, L: int, Q: int, alloc_steps: int,
                train_only: bool = False, agg_kernel: bool = False,
                lane_chunk: Optional[int] = None, done_b=None,
                codec=None, codec_state_b=None, codec_keys_b=None):
    """One fused round for S lanes at once.

    Population/data arrays carry a leading lane axis (S, ...); sched_b
    and assign_b are (S, H); sizes_b (S, N) holds the Algorithm-1
    aggregation weights. Gathers each lane's cohort and vmaps
    ``round_step_core``, returning (params_b, (T_i, E_i)) with (S,)
    cost vectors. train_only=True skips resource allocation and cost
    bookkeeping entirely (accuracy-only sweeps like Fig. 3/4) and
    returns zero costs. agg_kernel=True routes every lane's Algorithm-1
    aggregation through the lane-batched ``hier_agg`` Pallas kernel —
    the vmap hits the kernel's ``custom_vmap`` rule, so all S lanes
    share ONE (S, P/BP)-grid launch per aggregation instead of falling
    back to S per-lane interpret calls. done_b: optional (S,) bool mask
    of lanes that already reached the sweep's accuracy target — a done
    lane's model is frozen (params pass through unchanged) and it stops
    accruing training compute (its T_i/E_i come back zero), so finished
    lanes no longer distort the sweep's cost totals. lane_chunk: see
    ``_sweep_round_lanes`` — cache-blocked sequential chunks for CPU
    hosts, None (one vmapped program) for accelerators.
    """
    if done_b is None:
        done_b = jnp.zeros((sched_b.shape[0],), bool)
    return _sweep_round_lanes(
        apply_fn, sp, params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b,
        y_b, mask_b, sizes_b, sched_b, assign_b, lr, done_b, M=M, L=L, Q=Q,
        alloc_steps=alloc_steps, train_only=train_only,
        agg_kernel=agg_kernel, lane_chunk=lane_chunk, codec=codec,
        codec_state_b=codec_state_b, codec_keys_b=codec_keys_b)


@functools.partial(jax.jit, static_argnames=(
    "apply_fn", "sp", "M", "L", "Q", "alloc_steps", "train_only",
    "agg_kernel", "mesh", "lane_chunk", "codec"))
def sweep_round_sharded(apply_fn, sp: cm.SystemParams, params_b, u_b, D_b,
                        p_b, g_b, g_cloud_b, B_m_b, X_b, y_b, mask_b,
                        sizes_b, sched_b, assign_b, lr, *, M: int, L: int,
                        Q: int, alloc_steps: int, mesh,
                        train_only: bool = False, agg_kernel: bool = False,
                        lane_chunk: Optional[int] = None, done_b=None,
                        codec=None, codec_state_b=None, codec_keys_b=None):
    """``sweep_round`` laid out over a 1-D ``Mesh(("lane",))``.

    Same args/semantics as ``sweep_round`` plus a static ``mesh``
    (``launch.mesh.sweep_mesh()``): the stacked lane axis S — which must
    be a multiple of the mesh's device count; ``SweepRunner`` pads with
    dead done-masked lanes — is block-partitioned over the devices and
    every device runs the identical vmapped round body on its S/d lane
    block as ONE SPMD program. Lanes are independent (no collectives):
    ``out_specs`` just re-stacks the per-device blocks. Scheduling /
    assignment stay host-side in ``SweepRunner.run`` — nothing inside
    the sharded region calls back to the host, which is what keeps the
    hfel/drl assignment hooks shard-compatible (their jitted searches
    run on the default device *between* sharded rounds). lane_chunk
    applies *within* each device's lane block (must divide S/d; see
    ``_sweep_round_lanes`` for when to use it).
    """
    if done_b is None:
        done_b = jnp.zeros((sched_b.shape[0],), bool)
    lane, rep = PartitionSpec("lane"), PartitionSpec()
    codec_on = codec is not None and codec.active

    def block(params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b, y_b,
              mask_b, sizes_b, sched_b, assign_b, lr, done_b, *cstate):
        kw = {}
        if codec_on:
            kw = dict(codec=codec, codec_state_b=(cstate[0], cstate[1]),
                      codec_keys_b=cstate[2])
        return _sweep_round_lanes(
            apply_fn, sp, params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b,
            X_b, y_b, mask_b, sizes_b, sched_b, assign_b, lr, done_b,
            M=M, L=L, Q=Q, alloc_steps=alloc_steps, train_only=train_only,
            agg_kernel=agg_kernel, lane_chunk=lane_chunk, **kw)

    in_specs = (lane,) * 13 + (rep, lane)
    out_specs = (lane, (lane, lane))
    args = (params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b, y_b,
            mask_b, sizes_b, sched_b, assign_b, lr, done_b)
    if codec_on:
        in_specs = in_specs + (lane, lane, lane)
        out_specs = (lane, (lane, lane), (lane, lane))
        args = args + (codec_state_b[0], codec_state_b[1], codec_keys_b)
    sharded = shard_map(block, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return sharded(*args)


def _sweep_eval_lanes(apply_fn, params_b, Xt_b, yt_b):
    """Traceable lane-vmapped full-batch test accuracy — shared by the
    per-round ``_sweep_eval`` jit and the in-scan eval of the fused
    sweep (where it feeds the done-mask early-exit)."""
    return jax.vmap(
        lambda prm, Xt, yt: jnp.mean(
            (jnp.argmax(apply_fn(prm, Xt), axis=-1) == yt)
            .astype(jnp.float32))
    )(params_b, Xt_b, yt_b)


_sweep_eval = functools.partial(jax.jit, static_argnames=("apply_fn",))(
    _sweep_eval_lanes)


# ------------------------------------------------------------ fused scan

_HFEL_FUSED_DEFAULTS = dict(n_transfer=40, n_exchange=80, n_candidates=16,
                            warm_steps=None, accept_top=4)


def _sweep_scan_lanes(apply_fn, sp, sp_assign, params_b, u_b, D_b, p_b,
                      g_b, g_cloud_b, B_m_b, X_b, y_b, mask_b, sizes_b,
                      dev_pos_b, edge_pos_b, Xt_b, yt_b, sched_rs,
                      sched_state_b, assign_keys_b, done_b, drl_params, lr,
                      codec_state_b, codec_base_b, codec_r0,
                      *, M: int, L: int, Q: int, alloc_steps: int,
                      train_only: bool, agg_kernel: bool,
                      lane_chunk: Optional[int], assign: str, hfel_cfg,
                      target_acc: Optional[float], n_rounds: int,
                      traced_sched, codec=None):
    """Traceable R-round S-lane sweep body: ``lax.scan`` over rounds of
    (scheduler step -> traced assignment -> lane-vmapped round body ->
    in-scan eval -> done-mask update). Shared by the single-device
    ``sweep_scan`` jit and the ``shard_map`` blocks of
    ``sweep_scan_sharded``.

    Scheduling comes either from the precomputed ``sched_rs`` (R, S, H)
    tensor (host schedulers; ``traced_sched=None``) or, with a
    ``traced_sched`` ``TracedFedAvg``, from in-scan draws against the
    carried ``sched_state_b`` pytree (one PRNG key per lane).
    Assignment (``assign`` in mod|geo|drl|hfel) runs fully in-trace per
    round; hfel consumes one split of the carried ``assign_keys_b`` per
    round (split unconditionally for every assigner so the carry
    structure — and hence fused-vs-oracle parity — is mode-independent).
    The done-mask semantics mirror the host loop exactly: a lane's
    round outputs are recorded, then its done flag absorbs
    ``acc >= target_acc``, freezing it from the NEXT round on.

    Returns ((params_b, done_b, sched_state_b, assign_keys_b),
    (acc (R, S), T_i (R, S), E_i (R, S))).

    With an active ``codec`` the carry additionally holds the per-lane
    error-feedback state ``codec_state_b`` and a round counter (seeded
    at ``codec_r0``) — codec keys are re-derived in-scan as
    ``fold_in(codec_base_b[lane], round)``, the exact stream the host
    loop draws, so fused and host compressed sweeps stay in lockstep.
    """
    hfel_kw = dict(hfel_cfg) if hfel_cfg is not None else None
    codec_on = codec is not None and codec.active

    def assign_lane(u, D, p, g, g_cloud, B_m, dev_pos, edge_pos, sched,
                    key):
        if assign == "mod":
            return (sched % M).astype(jnp.int32)
        if assign == "geo":
            return geo_assign_traced(dev_pos, edge_pos, sched)
        if assign == "drl":
            return drl_assign_traced(drl_params, u, D, p, g, sched)
        a, _ = hfel_search_traced(
            sp_assign, u[sched], D[sched], p[sched], g[sched], B_m,
            g_cloud, key, alloc_steps=alloc_steps, **hfel_kw)
        return a

    def step(carry, xs):
        if codec_on:
            (params_b, done_b, sched_state_b, keys_b, codec_state_b,
             r) = carry
        else:
            params_b, done_b, sched_state_b, keys_b = carry
        if traced_sched is None:
            sched_b = xs
        else:
            sched_state_b, sched_b = jax.vmap(traced_sched.step)(
                sched_state_b)
        splits = jax.vmap(jax.random.split)(keys_b)        # (S, 2, 2)
        keys_b, sub_b = splits[:, 0], splits[:, 1]
        assign_b = jax.vmap(assign_lane)(
            u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, dev_pos_b, edge_pos_b,
            sched_b, sub_b)
        if codec_on:
            ckeys_b = jax.vmap(
                lambda k: jax.random.fold_in(k, r))(codec_base_b)
            new_params, (T_i, E_i), codec_state_b = _sweep_round_lanes(
                apply_fn, sp, params_b, u_b, D_b, p_b, g_b, g_cloud_b,
                B_m_b, X_b, y_b, mask_b, sizes_b, sched_b, assign_b, lr,
                done_b, M=M, L=L, Q=Q, alloc_steps=alloc_steps,
                train_only=train_only, agg_kernel=agg_kernel,
                lane_chunk=lane_chunk, codec=codec,
                codec_state_b=codec_state_b, codec_keys_b=ckeys_b)
        else:
            new_params, (T_i, E_i) = _sweep_round_lanes(
                apply_fn, sp, params_b, u_b, D_b, p_b, g_b, g_cloud_b,
                B_m_b, X_b, y_b, mask_b, sizes_b, sched_b, assign_b, lr,
                done_b, M=M, L=L, Q=Q, alloc_steps=alloc_steps,
                train_only=train_only, agg_kernel=agg_kernel,
                lane_chunk=lane_chunk)
        acc = _sweep_eval_lanes(apply_fn, new_params, Xt_b, yt_b)
        if target_acc is not None:
            done_b = done_b | (acc >= target_acc)
        if codec_on:
            return (new_params, done_b, sched_state_b, keys_b,
                    codec_state_b, r + 1), (acc, T_i, E_i)
        return (new_params, done_b, sched_state_b, keys_b), (acc, T_i, E_i)

    carry0 = (params_b, done_b, sched_state_b, assign_keys_b)
    if codec_on:
        carry0 = carry0 + (codec_state_b, codec_r0)
    xs = sched_rs if traced_sched is None else None
    return jax.lax.scan(step, carry0, xs,
                        length=n_rounds if xs is None else None)


_SCAN_STATICS = ("apply_fn", "sp", "sp_assign", "M", "L", "Q",
                 "alloc_steps", "train_only", "agg_kernel", "lane_chunk",
                 "assign", "hfel_cfg", "target_acc", "n_rounds",
                 "traced_sched", "codec")


@functools.partial(jax.jit, static_argnames=_SCAN_STATICS)
def sweep_scan(apply_fn, sp: cm.SystemParams, sp_assign, params_b, u_b,
               D_b, p_b, g_b, g_cloud_b, B_m_b, X_b, y_b, mask_b, sizes_b,
               dev_pos_b, edge_pos_b, Xt_b, yt_b, sched_rs, sched_state_b,
               assign_keys_b, done_b, drl_params, lr, codec_state_b=None,
               codec_base_b=None, codec_r0=None, *, M: int, L: int,
               Q: int, alloc_steps: int, train_only: bool = False,
               agg_kernel: bool = False, lane_chunk: Optional[int] = None,
               assign: str = "geo", hfel_cfg=None,
               target_acc: Optional[float] = None, n_rounds: int = 1,
               traced_sched=None, codec=None):
    """An R-round, S-lane sweep as ONE jitted dispatch.

    The whole-sweep analogue of ``sweep_round``: scheduling, assignment
    (including the traced HFEL K-candidate search and D3QN deployment),
    R rounds of the fused engine, per-round eval and the done-mask
    early-exit all live inside a single ``lax.scan`` — zero host
    round-trips between rounds. Population/data arrays as in
    ``sweep_round`` plus dev_pos_b/edge_pos_b (S, ·, 2) positions
    (traced geo) and Xt_b/yt_b test stacks (in-scan eval).
    ``sp_assign`` is the SystemParams the hfel objective scores with
    (the host path's assigner uses the un-patched sweep params, not the
    model-bits-patched round ``sp``). See ``_sweep_scan_lanes`` for the
    scheduling/assignment operand semantics and the carry layout.
    """
    return _sweep_scan_lanes(
        apply_fn, sp, sp_assign, params_b, u_b, D_b, p_b, g_b, g_cloud_b,
        B_m_b, X_b, y_b, mask_b, sizes_b, dev_pos_b, edge_pos_b, Xt_b,
        yt_b, sched_rs, sched_state_b, assign_keys_b, done_b, drl_params,
        lr, codec_state_b, codec_base_b, codec_r0,
        M=M, L=L, Q=Q, alloc_steps=alloc_steps, train_only=train_only,
        agg_kernel=agg_kernel, lane_chunk=lane_chunk, assign=assign,
        hfel_cfg=hfel_cfg, target_acc=target_acc, n_rounds=n_rounds,
        traced_sched=traced_sched, codec=codec)


@functools.partial(jax.jit, static_argnames=_SCAN_STATICS + ("mesh",))
def sweep_scan_sharded(apply_fn, sp: cm.SystemParams, sp_assign, params_b,
                       u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b, y_b,
                       mask_b, sizes_b, dev_pos_b, edge_pos_b, Xt_b, yt_b,
                       sched_rs, sched_state_b, assign_keys_b, done_b,
                       drl_params, lr, codec_state_b=None,
                       codec_base_b=None, codec_r0=None, *, M: int, L: int,
                       Q: int, alloc_steps: int, mesh,
                       train_only: bool = False,
                       agg_kernel: bool = False,
                       lane_chunk: Optional[int] = None,
                       assign: str = "geo", hfel_cfg=None,
                       target_acc: Optional[float] = None,
                       n_rounds: int = 1, traced_sched=None, codec=None):
    """``sweep_scan`` laid out over a 1-D ``Mesh(("lane",))``.

    Each device runs the ENTIRE R-round scan — traced scheduling,
    assignment search, round body, eval, done-mask — on its S/d lane
    block as one SPMD program: still exactly one dispatch for the whole
    sweep, now lane-parallel. Lanes are independent, so there are no
    collectives; the (R, S, H) schedule tensor and the (R, S) outputs
    shard on their lane axis only (``parallel.sharding.round_lane_spec``).
    S must be a multiple of the device count (``SweepRunner`` pads with
    dead done-masked lanes, exactly as in ``sweep_round_sharded``).
    """
    from repro.parallel.sharding import round_lane_spec
    lane, rep = PartitionSpec("lane"), PartitionSpec()
    rlane = round_lane_spec()
    codec_on = codec is not None and codec.active

    def block(params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b, y_b,
              mask_b, sizes_b, dev_pos_b, edge_pos_b, Xt_b, yt_b,
              sched_rs, sched_state_b, assign_keys_b, done_b, drl_params,
              lr, *cargs):
        cstate, cbase, cr0 = cargs if codec_on else (None, None, None)
        return _sweep_scan_lanes(
            apply_fn, sp, sp_assign, params_b, u_b, D_b, p_b, g_b,
            g_cloud_b, B_m_b, X_b, y_b, mask_b, sizes_b, dev_pos_b,
            edge_pos_b, Xt_b, yt_b, sched_rs, sched_state_b,
            assign_keys_b, done_b, drl_params, lr, cstate, cbase, cr0,
            M=M, L=L, Q=Q,
            alloc_steps=alloc_steps, train_only=train_only,
            agg_kernel=agg_kernel, lane_chunk=lane_chunk, assign=assign,
            hfel_cfg=hfel_cfg, target_acc=target_acc, n_rounds=n_rounds,
            traced_sched=traced_sched, codec=codec)

    in_specs = (lane,) * 15 + (rlane, lane, lane, lane, rep, rep)
    carry_specs = (lane, lane, lane, lane)
    args = (params_b, u_b, D_b, p_b, g_b, g_cloud_b, B_m_b, X_b,
            y_b, mask_b, sizes_b, dev_pos_b, edge_pos_b, Xt_b,
            yt_b, sched_rs, sched_state_b, assign_keys_b, done_b,
            drl_params, lr)
    if codec_on:
        in_specs = in_specs + (lane, lane, rep)
        carry_specs = carry_specs + (lane, rep)
        args = args + (codec_state_b, codec_base_b, codec_r0)
    sharded = shard_map(
        block, mesh=mesh,
        in_specs=in_specs,
        out_specs=(carry_specs, (rlane, rlane, rlane)),
        check_rep=False)
    return sharded(*args)


def _mod_assign(pop: cm.Population, sched: np.ndarray, rng) -> np.ndarray:
    """Fixed round-robin assignment (Fig. 3/4 training-only sweeps)."""
    return np.asarray(sched) % pop.n_edges


def _geo_assign(pop: cm.Population, sched: np.ndarray, rng) -> np.ndarray:
    """Delegates to the canonical GeoAssigner (sp is unused by it)."""
    return np.asarray(GeoAssigner(None).assign(pop, sched, rng)[0])


ASSIGN_FNS: Dict[str, Callable] = {"mod": _mod_assign, "geo": _geo_assign}


def make_hfel_assign(sp: cm.SystemParams, *, n_transfer: int = 40,
                     n_exchange: int = 80, alloc_steps: int = 100,
                     n_candidates: int = 16) -> Callable:
    """Assignment callable driving the batched K-candidate HFEL search
    (``assign="hfel"`` in ``SweepRunner.run``). Reduced trial budget by
    default: sweeps re-assign every round, so per-round search latency
    matters more than squeezing the last percent of J(Ψ)."""
    from repro.core.assignment.hfel import HFELAssigner
    assigner = HFELAssigner(sp, n_transfer=n_transfer,
                            n_exchange=n_exchange, alloc_steps=alloc_steps,
                            search="batched", n_candidates=n_candidates)

    def fn(pop: cm.Population, sched: np.ndarray, rng) -> np.ndarray:
        return np.asarray(assigner.assign(pop, sched, rng)[0])

    return fn


def make_drl_assign(sp: cm.SystemParams, params) -> Callable:
    """Assignment callable wrapping a trained D3QN agent (greedy) —
    ``assign="drl"`` in ``SweepRunner.run``. ``params`` is the trained
    parameter pytree (``D3QNTrainer.params``); Q evaluation goes through
    the module-level jitted entry shared with the trainer, so all lanes
    reuse one compiled program."""
    from repro.core.assignment.drl import DRLAssigner
    assigner = DRLAssigner(sp, params)

    def fn(pop: cm.Population, sched: np.ndarray, rng) -> np.ndarray:
        return np.asarray(assigner.assign(pop, sched, rng)[0])

    return fn


class SweepRunner:
    """Vmapped multi-lane driver for the fused round engine.

    worlds: list of (Population, FederatedData), one per sweep lane —
    identical shapes required (same N devices, M edges, test-set size).
    Each lane gets its own model init, scheduler state and host RNG; the
    per-round compute of ALL lanes is a single jitted dispatch.

    shard=True lays the lane axis out over a 1-D ``Mesh(("lane",))``
    (``mesh``, default ``launch.mesh.sweep_mesh()`` over all local
    devices) and runs every round through ``sweep_round_sharded``: one
    SPMD program, each device owning an S/d lane block. S is padded up
    to a multiple of the device count with *dead lanes* — clones of lane
    0 that are born with the per-lane done-mask set, so they freeze
    their params, report zero costs and never consume host rng or
    assignment search; all outputs are unpadded back to the real S. The
    shard=False vmapped path is the parity oracle
    (``tests/test_sweep_shard.py``).

    lane_chunk=k executes lanes in sequential vmapped chunks of k (per
    device block when sharded) instead of one whole-axis vmap — a CPU
    cache-blocking knob, see ``_sweep_round_lanes``; leave None on
    accelerators.
    """

    def __init__(self, sp: cm.SystemParams,
                 worlds: Sequence[Tuple[cm.Population, FederatedData]],
                 *, lr: float = 0.01, alloc_steps: int = 100,
                 model_seed: int = 0, agg_kernel: bool = False,
                 shard: bool = False, mesh=None,
                 lane_chunk: Optional[int] = None,
                 compression: Optional[comp.CompressionConfig] = None,
                 arch: str = "hfl-cnn"):
        assert len(worlds) >= 1
        self.sp, self.lr, self.alloc_steps = sp, lr, alloc_steps
        self.arch = arch
        self.spec = get_hfl_spec(arch)
        self.agg_kernel = agg_kernel
        self.lane_chunk = lane_chunk
        self.codec = (compression if compression is not None
                      else comp.CompressionConfig())
        self.pops = [w[0] for w in worlds]
        self.feds = [w[1] for w in worlds]
        self.S = len(worlds)
        self.M = self.pops[0].n_edges
        self.N = self.feds[0].n_devices

        if shard:
            from repro.launch.mesh import sweep_mesh
            from repro.parallel.sharding import pad_lanes
            self.mesh = mesh if mesh is not None else sweep_mesh()
            if tuple(self.mesh.axis_names) != ("lane",):
                raise ValueError("shard=True needs a 1-D ('lane',) mesh "
                                 f"(got axes {self.mesh.axis_names})")
            self.S_pad = pad_lanes(self.S, self.mesh.devices.size)
            block = self.S_pad // self.mesh.devices.size
        else:
            self.mesh = None
            self.S_pad = self.S
            block = self.S
        if lane_chunk is not None and block % lane_chunk != 0:
            raise ValueError(
                f"lane_chunk={lane_chunk} must divide the per-device "
                f"lane block ({block})")
        self._n_dead = self.S_pad - self.S

        Dmax = max(int(max(len(y) for y in fed.y)) for fed in self.feds)
        padded = [pad_device_data(fed, Dmax) for fed in self.feds]
        self.X_b = jnp.stack([p[0] for p in padded])      # (S, N, Dmax, ...)
        self.y_b = jnp.stack([p[1] for p in padded])
        self.mask_b = jnp.stack([p[2] for p in padded])
        self.Xt_b = jnp.stack([jnp.asarray(f.X_test) for f in self.feds])
        self.yt_b = jnp.stack([jnp.asarray(f.y_test) for f in self.feds])
        self.fed_sizes_b = jnp.stack(
            [jnp.asarray(f.sizes, jnp.float32) for f in self.feds])
        self.u_b = jnp.stack([p.u for p in self.pops])
        self.D_b = jnp.stack([p.D for p in self.pops])
        self.p_b = jnp.stack([p.p for p in self.pops])
        self.g_b = jnp.stack([p.g for p in self.pops])
        self.g_cloud_b = jnp.stack([p.g_cloud for p in self.pops])
        self.B_m_b = jnp.stack([p.B_m for p in self.pops])
        self.dev_pos_b = jnp.stack(
            [jnp.asarray(p.dev_pos) for p in self.pops])
        self.edge_pos_b = jnp.stack(
            [jnp.asarray(p.edge_pos) for p in self.pops])

        # per-lane model inits from the arch spec (lane worlds share
        # shapes, so feds[0] fixes the payload geometry for all lanes)
        keys = jax.random.split(jax.random.PRNGKey(model_seed), self.S)
        inits = [self.spec.init_fn(k, self.feds[0]) for k in keys]
        self.params0 = jax.tree.map(lambda *xs: jnp.stack(xs), *inits)
        self.apply_fn = self.spec.apply_fn
        self.model_bits = tree_bytes(inits[0]) * 8
        # codec="none" gives exactly model_bits, so the sp the round jits
        # see is value-identical to the uncompressed runner's (same jit
        # cache entry -> bitwise parity).
        self.uplink_bits = comp.message_bits(self.codec, inits[0])

        if self.mesh is not None:
            self._shard_lane_stacks()

    def _codec_state0(self):
        """Fresh lane-stacked error-feedback state: ``(dev_resid
        (S_pad, N, ...), edge_resid (S_pad, M, ...))`` zero trees shaped
        like one lane's params, lane-sharded when the runner is. None for
        the identity codec."""
        if not self.codec.active:
            return None
        p0 = jax.tree.map(lambda x: x[0], self.params0)
        state = (comp.init_state(self.codec, p0, self.N),
                 comp.init_state(self.codec, p0, self.M))
        state = jax.tree.map(
            lambda z: jnp.zeros((self.S_pad,) + z.shape, z.dtype), state)
        if self.mesh is not None:
            from repro.parallel.sharding import lane_sharding
            sh = lane_sharding(self.mesh)
            state = jax.tree.map(lambda z: jax.device_put(z, sh), state)
        return state

    def _codec_base_keys(self, seeds):
        """Per-lane codec key bases ``fold_in(PRNGKey(codec.seed),
        lane_seed)`` — the host loop folds the round index in per round,
        the fused scan folds the carried round counter in in-scan, so
        both engines draw the identical ``compression.round_key``
        stream."""
        lane_seeds = jnp.asarray(
            list(seeds) + [seeds[0]] * self._n_dead, jnp.uint32)
        base = jax.random.PRNGKey(self.codec.seed)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(lane_seeds)
        if self.mesh is not None:
            from repro.parallel.sharding import lane_sharding
            keys = jax.device_put(keys, lane_sharding(self.mesh))
        return keys

    def _shard_lane_stacks(self):
        """Pad every lane-stacked array up to S_pad with clones of lane 0
        (dead lanes: done-masked from round 0, outputs discarded) and lay
        the lane axis out over the mesh so round inputs are born resident
        on their owning devices instead of resharding every dispatch."""
        from repro.parallel.sharding import lane_sharding
        sh = lane_sharding(self.mesh)

        def prep(a):
            if self._n_dead:
                a = jnp.concatenate(
                    [a, jnp.repeat(a[:1], self._n_dead, axis=0)])
            return jax.device_put(a, sh)

        for name in ("X_b", "y_b", "mask_b", "Xt_b", "yt_b", "fed_sizes_b",
                     "u_b", "D_b", "p_b", "g_b", "g_cloud_b", "B_m_b",
                     "dev_pos_b", "edge_pos_b"):
            setattr(self, name, prep(getattr(self, name)))
        self.params0 = jax.tree.map(prep, self.params0)

    # ---------------------------------------------------------------- run

    def run(self, schedulers: Sequence, n_rounds: int,
            assign: Union[str, Callable] = "geo",
            seeds: Optional[Sequence[int]] = None,
            target_acc: Optional[float] = None,
            sizes: str = "pop", train_only: bool = False,
            drl_params=None, fused: Union[bool, str] = False,
            assign_seed: int = 0,
            hfel_opts: Optional[Dict] = None) -> Dict:
        """Run n_rounds of all S lanes; lane s uses schedulers[s].

        assign: "geo" | "mod" | "hfel" (batched K-candidate search via
        ``make_hfel_assign``) | "drl" (greedy trained D3QN agent via
        ``make_drl_assign``; requires ``drl_params``) |
        callable(pop, sched, rng) -> (H,) edges.
        drl_params: trained D3QN parameter pytree
        (``D3QNTrainer.params``), consumed only by ``assign="drl"``.
        sizes: Algorithm-1 aggregation weights — "pop" (cost-model pop.D,
        HFLFramework semantics) or "fed" (actual federated partition
        sizes, the Fig. 3/4 training-curve semantics).
        train_only=True skips resource allocation / cost bookkeeping
        (T_i, E_i are zeros).
        Early stop is per lane: a lane that reaches ``target_acc`` is
        marked done — its model freezes, its assignment search is
        skipped (the lane reuses its last schedule/assignment) and its
        T_i/E_i rows are zero from then on — and the loop breaks once
        every lane is done.

        fused=True runs the whole sweep — scheduling, traced assignment,
        R rounds, eval, done-mask — as ONE jitted dispatch
        (``sweep_scan`` / ``sweep_scan_sharded``); ``fused="oracle"``
        drives the identical traced step in a per-round host loop (one
        dispatch per round) and is the fused path's parity baseline.
        Fused mode needs a *named* assigner (mod/geo/drl/hfel — the
        traced twins run in-scan; callables cannot be traced); hfel
        proposals draw from a JAX key stream seeded by ``assign_seed``
        (host-rng-free), tunable via ``hfel_opts`` (n_transfer,
        n_exchange, n_candidates, warm_steps, accept_top — defaults
        match ``make_hfel_assign``). Schedulers may be the host state
        machines (their (R, S, H) schedules are precomputed up front —
        exact, since scheduling never depends on training state) or
        per-lane ``TracedFedAvg`` instances (drawn in-scan from carried
        PRNG-key state). The result dict gains ``n_dispatches``.

        Returns {"acc": (S, R), "T_i": (S, R), "E_i": (S, R),
        "msg_bits_per_round": float, "iters": (S,) rounds to target_acc
        (or n_rounds), "obj": (S, R)} as numpy arrays.
        """
        assert len(schedulers) == self.S
        if fused not in (False, True, "oracle"):
            raise ValueError(f"fused must be False, True or 'oracle', "
                             f"got {fused!r}")
        if fused:
            return self._run_fused(
                schedulers, n_rounds, assign=assign, seeds=seeds,
                target_acc=target_acc, sizes=sizes, train_only=train_only,
                drl_params=drl_params, oracle=(fused == "oracle"),
                assign_seed=assign_seed, hfel_opts=hfel_opts)
        if isinstance(assign, str):
            if assign == "hfel":
                assign_fn = make_hfel_assign(self.sp,
                                             alloc_steps=self.alloc_steps)
            elif assign == "drl":
                if drl_params is None:
                    raise ValueError(
                        "assign='drl' needs drl_params (a trained "
                        "D3QNTrainer.params pytree)")
                assign_fn = make_drl_assign(self.sp, drl_params)
            else:
                assign_fn = ASSIGN_FNS[assign]
        else:
            assign_fn = assign
        if sizes not in ("pop", "fed"):
            raise ValueError(f"sizes must be 'pop' or 'fed', got {sizes!r}")
        sizes_b = self.D_b if sizes == "pop" else self.fed_sizes_b
        if seeds is None:
            seeds = list(range(self.S))
        rngs = [np.random.default_rng(s) for s in seeds]
        sp = dataclasses.replace(self.sp,
                                 model_bits=float(self.uplink_bits))
        codec_on = self.codec.active
        cstate = self._codec_state0()
        cbase = self._codec_base_keys(seeds) if codec_on else None

        params_b = self.params0
        accs: List[np.ndarray] = []
        Ts: List[np.ndarray] = []
        Es: List[np.ndarray] = []
        H = None
        # dead pad lanes (sharding only) are done from round 0: frozen
        # params, zero costs, no host rng / search spend, outputs sliced
        # away below.
        done = np.zeros(self.S_pad, bool)
        done[self.S:] = True
        scheds = [None] * self.S
        assigns = [None] * self.S
        for r_i in range(n_rounds):
            # done lanes are frozen: reuse their last schedule/assignment
            # instead of spending scheduler rng and assignment search on
            # a lane that no longer trains.
            scheds = [scheds[s] if done[s]
                      else np.asarray(schedulers[s].schedule(rngs[s]))
                      for s in range(self.S)]
            # IKC/VKC lanes can come up short of the nominal cohort when a
            # lane's clustering left clusters empty (K' < K); top the short
            # lanes up from their unscheduled pool (Alg. 3/4 lines 12-15)
            # so every lane shares one (S, H) shape.
            H = max(len(s) for s in scheds)
            # route through the scheduler's topup_to so rotation-state
            # policies (IKC) record the extra picks in G_k; plain _topup
            # covers caller-supplied scheduler objects without one.
            scheds = [np.asarray(
                          schedulers[i].topup_to(s, H, rngs[i])
                          if hasattr(schedulers[i], "topup_to")
                          else _topup(list(s), self.N, H, rngs[i]))
                      if len(s) < H else s
                      for i, s in enumerate(scheds)]
            assigns = [assigns[s] if done[s]
                       else np.asarray(assign_fn(self.pops[s], scheds[s],
                                                 rngs[s]))
                       for s in range(self.S)]
            # dead pad lanes alias lane 0's cohort (no rng consumed; their
            # round output is masked by done and discarded).
            pad = [scheds[0]] * self._n_dead
            sched_b = jnp.asarray(np.stack(scheds + pad))
            assign_b = jnp.asarray(np.stack(
                assigns + [assigns[0]] * self._n_dead))
            ckw = {}
            if codec_on:
                ckw = dict(codec=self.codec, codec_state_b=cstate,
                           codec_keys_b=jax.vmap(
                               lambda k: jax.random.fold_in(k, r_i))(cbase))
            if self.mesh is not None:
                out = sweep_round_sharded(
                    self.apply_fn, sp, params_b, self.u_b, self.D_b,
                    self.p_b, self.g_b, self.g_cloud_b, self.B_m_b,
                    self.X_b, self.y_b, self.mask_b, sizes_b, sched_b,
                    assign_b, self.lr, M=self.M, L=sp.L, Q=sp.Q,
                    alloc_steps=self.alloc_steps, mesh=self.mesh,
                    train_only=train_only, agg_kernel=self.agg_kernel,
                    lane_chunk=self.lane_chunk, done_b=jnp.asarray(done),
                    **ckw)
            else:
                out = sweep_round(
                    self.apply_fn, sp, params_b, self.u_b, self.D_b,
                    self.p_b, self.g_b, self.g_cloud_b, self.B_m_b,
                    self.X_b, self.y_b, self.mask_b, sizes_b, sched_b,
                    assign_b, self.lr, M=self.M, L=sp.L, Q=sp.Q,
                    alloc_steps=self.alloc_steps, train_only=train_only,
                    agg_kernel=self.agg_kernel, lane_chunk=self.lane_chunk,
                    done_b=jnp.asarray(done), **ckw)
            if codec_on:
                params_b, (T_i, E_i), cstate = out
            else:
                params_b, (T_i, E_i) = out
            acc_full = self._eval(params_b)              # (S_pad,)
            acc = acc_full[:self.S]
            accs.append(acc)
            Ts.append(np.asarray(T_i)[:self.S])
            Es.append(np.asarray(E_i)[:self.S])
            if target_acc is not None:
                done = done | (acc_full >= target_acc)
                if done.all():
                    break

        acc_a = np.stack(accs, axis=1)                  # (S, R)
        T_a = np.stack(Ts, axis=1)
        E_a = np.stack(Es, axis=1)
        R = acc_a.shape[1]
        if target_acc is not None:
            reached = acc_a >= target_acc
            iters = np.where(reached.any(axis=1),
                             reached.argmax(axis=1) + 1, R)
        else:
            iters = np.full(self.S, R)
        msg_bits = cm.round_msg_bits(self.sp, sp.Q * H, self.M,
                                     msg_bits=self.uplink_bits)
        return {"acc": acc_a, "T_i": T_a, "E_i": E_a,
                "obj": E_a + sp.lam * T_a, "iters": iters,
                "msg_bits_per_round": float(msg_bits), "H": H,
                "codec": self.codec.codec,
                "uplink_bits_per_msg": float(self.uplink_bits),
                "uplink_bytes_per_round": float(msg_bits / 8)}

    # --------------------------------------------------------- fused run

    def _run_fused(self, schedulers: Sequence, n_rounds: int, *,
                   assign, seeds, target_acc, sizes, train_only,
                   drl_params, oracle: bool, assign_seed: int,
                   hfel_opts) -> Dict:
        """``run(fused=...)`` body: one ``sweep_scan`` dispatch for the
        whole sweep (oracle=False) or a per-round host loop over the
        identical traced step (oracle=True, the parity baseline)."""
        if not isinstance(assign, str):
            raise ValueError(
                "fused sweeps need a named assigner (mod/geo/drl/hfel) — "
                "callables cannot run inside the scan")
        if assign not in ("mod", "geo", "drl", "hfel"):
            raise ValueError(f"unknown assign {assign!r} for fused run")
        if assign == "drl" and drl_params is None:
            raise ValueError("assign='drl' needs drl_params (a trained "
                             "D3QNTrainer.params pytree)")
        if sizes not in ("pop", "fed"):
            raise ValueError(f"sizes must be 'pop' or 'fed', got {sizes!r}")
        if hfel_opts and assign != "hfel":
            raise ValueError("hfel_opts only applies to assign='hfel'")
        hfel_cfg = None
        if assign == "hfel":
            opts = dict(hfel_opts or {})
            bad = set(opts) - set(_HFEL_FUSED_DEFAULTS)
            if bad:
                raise ValueError(
                    f"unknown hfel_opts keys {sorted(bad)}; valid: "
                    f"{sorted(_HFEL_FUSED_DEFAULTS)} (alloc_steps is the "
                    "runner's constructor knob)")
            hfel_cfg = tuple(sorted({**_HFEL_FUSED_DEFAULTS, **opts}.items()))
        sizes_b = self.D_b if sizes == "pop" else self.fed_sizes_b
        if seeds is None:
            seeds = list(range(self.S))
        sp = dataclasses.replace(self.sp,
                                 model_bits=float(self.uplink_bits))
        codec_on = self.codec.active
        cstate = self._codec_state0()
        cbase = self._codec_base_keys(seeds) if codec_on else None
        cr = jnp.int32(0) if codec_on else None

        # -- scheduling: in-scan TracedFedAvg state, or an exact host
        #    precompute (scheduling never reads training state, so the
        #    (R, S, H) tensor reproduces the host loop's draws verbatim).
        n_traced = sum(isinstance(s, TracedFedAvg) for s in schedulers)
        if n_traced == self.S:
            traced_sched = schedulers[0]
            if any(s != traced_sched for s in schedulers):
                raise ValueError(
                    "fused TracedFedAvg lanes must share one (n_devices, "
                    "H) config — per-lane variation lives in the seed")
            H = traced_sched.H
            states = [traced_sched.init_state(seeds[s])
                      for s in range(self.S)]
            states += [states[0]] * self._n_dead
            sched_state_b = jnp.stack(states)
            sched_rs = None
        elif n_traced:
            raise ValueError("cannot mix TracedFedAvg and host schedulers "
                             "in one fused run")
        else:
            traced_sched = None
            sched_state_b = None
            rngs = [np.random.default_rng(s) for s in seeds]
            rounds = []
            H = None
            for _ in range(n_rounds):
                # identical rng-consumption order to the host loop: all
                # lanes' schedule draws, then all lanes' topups.
                scheds = [np.asarray(schedulers[s].schedule(rngs[s]))
                          for s in range(self.S)]
                H_r = max(len(s) for s in scheds)
                scheds = [np.asarray(
                              schedulers[i].topup_to(s, H_r, rngs[i])
                              if hasattr(schedulers[i], "topup_to")
                              else _topup(list(s), self.N, H_r, rngs[i]))
                          if len(s) < H_r else s
                          for i, s in enumerate(scheds)]
                if H is None:
                    H = H_r
                elif H_r != H:
                    raise ValueError(
                        f"fused sweeps need a round-constant cohort size "
                        f"(got H={H} then H={H_r}); use the per-round host "
                        "path for schedulers whose worst-case cohort "
                        "varies across rounds")
                rounds.append(np.stack(scheds + [scheds[0]] * self._n_dead))
            sched_rs = jnp.asarray(np.stack(rounds))     # (R, S_pad, H)

        base = jax.random.PRNGKey(assign_seed)
        lane_seeds = jnp.asarray(
            list(seeds) + [seeds[0]] * self._n_dead, jnp.uint32)
        assign_keys_b = jax.vmap(
            lambda s: jax.random.fold_in(base, s))(lane_seeds)
        done0 = np.zeros(self.S_pad, bool)
        done0[self.S:] = True
        done_b = jnp.asarray(done0)
        params_b = self.params0
        statics = dict(M=self.M, L=sp.L, Q=sp.Q, alloc_steps=self.alloc_steps,
                       train_only=train_only, agg_kernel=self.agg_kernel,
                       lane_chunk=self.lane_chunk, assign=assign,
                       hfel_cfg=hfel_cfg, target_acc=target_acc,
                       traced_sched=traced_sched,
                       codec=self.codec if codec_on else None)
        if self.mesh is not None:
            fn = functools.partial(sweep_scan_sharded, mesh=self.mesh)
        else:
            fn = sweep_scan

        def dispatch(params_b, done_b, sched_state_b, assign_keys_b,
                     sched_rs, n_r, codec_state_b=None, codec_r0=None):
            return fn(self.apply_fn, sp, self.sp, params_b, self.u_b,
                      self.D_b, self.p_b, self.g_b, self.g_cloud_b,
                      self.B_m_b, self.X_b, self.y_b, self.mask_b, sizes_b,
                      self.dev_pos_b, self.edge_pos_b, self.Xt_b, self.yt_b,
                      sched_rs, sched_state_b, assign_keys_b, done_b,
                      drl_params if assign == "drl" else None, self.lr,
                      codec_state_b, cbase, codec_r0,
                      n_rounds=n_r, **statics)

        if oracle:
            # per-round host loop over the SAME traced step: the fused
            # path's dispatch-per-round parity baseline.
            accs, Ts, Es = [], [], []
            n_dispatches = 0
            for r in range(n_rounds):
                xs_r = None if sched_rs is None else sched_rs[r:r + 1]
                carry, (acc_r, T_r, E_r) = dispatch(
                    params_b, done_b, sched_state_b, assign_keys_b, xs_r, 1,
                    cstate, cr)
                if codec_on:
                    (params_b, done_b, sched_state_b, assign_keys_b,
                     cstate, cr) = carry
                else:
                    params_b, done_b, sched_state_b, assign_keys_b = carry
                n_dispatches += 1
                accs.append(np.asarray(acc_r)[0, :self.S])
                Ts.append(np.asarray(T_r)[0, :self.S])
                Es.append(np.asarray(E_r)[0, :self.S])
                if target_acc is not None and np.asarray(done_b).all():
                    break
            acc_a = np.stack(accs, axis=1)               # (S, R_run)
            T_a = np.stack(Ts, axis=1)
            E_a = np.stack(Es, axis=1)
        else:
            _, (acc_rs, T_rs, E_rs) = dispatch(
                params_b, done_b, sched_state_b, assign_keys_b, sched_rs,
                n_rounds, cstate, cr)
            n_dispatches = 1
            acc_a = np.asarray(acc_rs)[:, :self.S].T     # (S, R)
            T_a = np.asarray(T_rs)[:, :self.S].T
            E_a = np.asarray(E_rs)[:, :self.S].T
            if target_acc is not None:
                # trim trailing all-done rounds so the fused result is
                # row-for-row comparable with the early-breaking host loop
                # (done lanes' extra rows are frozen-acc / zero-cost).
                reached_by = np.maximum.accumulate(
                    acc_a >= target_acc, axis=1)
                all_done = reached_by.all(axis=0)
                if all_done.any():
                    R_eff = int(all_done.argmax()) + 1
                    acc_a = acc_a[:, :R_eff]
                    T_a = T_a[:, :R_eff]
                    E_a = E_a[:, :R_eff]

        R = acc_a.shape[1]
        if target_acc is not None:
            reached = acc_a >= target_acc
            iters = np.where(reached.any(axis=1),
                             reached.argmax(axis=1) + 1, R)
        else:
            iters = np.full(self.S, R)
        msg_bits = cm.round_msg_bits(self.sp, sp.Q * H, self.M,
                                     msg_bits=self.uplink_bits)
        return {"acc": acc_a, "T_i": T_a, "E_i": E_a,
                "obj": E_a + sp.lam * T_a, "iters": iters,
                "msg_bits_per_round": float(msg_bits), "H": H,
                "codec": self.codec.codec,
                "uplink_bits_per_msg": float(self.uplink_bits),
                "uplink_bytes_per_round": float(msg_bits / 8),
                "n_dispatches": n_dispatches}

    def _eval(self, params_b, batch: int = 512) -> np.ndarray:
        n = self.Xt_b.shape[1]
        accs, ns = [], []
        for i in range(0, n, batch):
            a = _sweep_eval(self.apply_fn, params_b,
                            self.Xt_b[:, i:i + batch],
                            self.yt_b[:, i:i + batch])
            accs.append(np.asarray(a))
            ns.append(min(batch, n - i))
        return np.average(np.stack(accs, axis=0), axis=0, weights=ns)

    # ---------------------------------------------------- ratio sweeps

    def sweep_ratios(self, ratios: Sequence[float], *, scheduler: str,
                     n_rounds: int, assign: Union[str, Callable] = "geo",
                     K: int = 10, seeds: Optional[Sequence[int]] = None,
                     target_acc: Optional[float] = None) -> Dict:
        """Paper-style scheduling-ratio sweep: H = ratio * N for each
        ratio in ``ratios`` (e.g. 0.3 / 0.5 / 1.0), each ratio one
        vmapped multi-lane run. Returns {ratio: run-result}."""
        if seeds is None:
            seeds = list(range(self.S))
        out = {}
        for r in ratios:
            H = max(1, int(round(r * self.N)))
            name = "fedavg" if H >= self.N else scheduler
            scheds = [build_scheduler(name, self.feds[s], self.sp, H, K=K,
                                      lr=self.lr, seed=seeds[s],
                                      arch=self.arch)
                      for s in range(self.S)]
            out[r] = self.run(scheds, n_rounds, assign=assign, seeds=seeds,
                              target_acc=target_acc)
        return out
