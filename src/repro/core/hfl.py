"""HFL training orchestration — Algorithm 1 (one global iteration) and the
hierarchical aggregation equations (2)-(3), plus test evaluation.

Faithful semantics: at global iteration i the scheduled cohort H_i is
partitioned over M edge servers (assignment Ψ_i). Each of Q edge
iterations runs L local full-batch GD steps per device from that device's
*edge* model, then data-size-weighted edge aggregation (2). After Q edge
iterations the cloud aggregates the edge models weighted by their cohort
data sizes (3).

Implementation: devices are vmapped. Edge/cloud aggregation has two
backends selected by ``agg_kernel``: the default masked XLA einsum
against the assignment one-hot, or (``agg_kernel=True``) the fused
masked-weight ``kernels/hier_agg`` Pallas kernel, which streams the
(H, P) delta matrix through VMEM once and builds the normalised (M, H)
weight panel in-kernel from the one-hot + device sizes (interpret mode
off-TPU). Both backends share the empty-edge fixup (edges with no
devices keep their model) and the eq.-(3) weights; the einsum path is
the parity oracle (``tests/test_kernels.py`` / ``test_round_engine.py``).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_train import cohort_local_sgd
from repro.data.partition import FederatedData


def pad_device_data(fed: FederatedData, Dmax: Optional[int] = None):
    """-> X (N, Dmax, ...), y (N, Dmax), mask (N, Dmax).

    X keeps the source dtype: images stay float32, token sequences stay
    integer (the model-zoo payloads index embeddings with them).
    """
    N = fed.n_devices
    Dmax = Dmax or int(max(len(y) for y in fed.y))
    sample_shape = fed.X[0].shape[1:]
    X = np.zeros((N, Dmax, *sample_shape), fed.X[0].dtype)
    y = np.zeros((N, Dmax), np.int32)
    mask = np.zeros((N, Dmax), np.float32)
    for n in range(N):
        d = min(len(fed.y[n]), Dmax)
        X[n, :d] = fed.X[n][:d]
        y[n, :d] = fed.y[n][:d]
        mask[n, :d] = 1.0
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask)


def hfl_global_iteration_core(apply_fn: Callable, global_params, X, y, mask,
                              sizes, assign, *, M: int, L: int, Q: int,
                              lr: float, agg_kernel: bool = False,
                              codec=None, dev_resid=None, edge_resid=None,
                              codec_key=None):
    """Algorithm 1, traceable core (no jit) — inlined by the fused round
    engine (``framework.round_step``) and vmapped by ``core.sweep``.

    X/y/mask: (H, Dmax, ...) for the scheduled cohort; sizes: (H,) D_n;
    assign: (H,) edge ids. ``agg_kernel=True`` routes eqs. (2)-(3)
    through the fused masked-weight Pallas kernel (the one-hot + sizes go
    in raw; the normalised weight panel is built in-kernel, and vmapped
    callers hit the lane-batched grid). Returns new global params.

    With an active ``codec`` (:class:`repro.core.compression.
    CompressionConfig`, a static arg), both uplinks are compressed:
    devices encode their post-SGD delta vs the edge model they pulled
    and edges aggregate the decoded deltas in delta space
    (``edge' = edge + Σ w·decode(encode(delta))``, exactly eq. (2) when
    the codec is lossless); after Q edge iterations each edge encodes
    its delta vs the global model for the cloud hop. ``dev_resid``
    ((H, ...) cohort-gathered) and ``edge_resid`` ((M, ...)) are the
    error-feedback accumulators, updated every message; ``codec_key``
    seeds stochastic rounding. Returns
    ``(new_params, new_dev_resid, new_edge_resid)`` in this mode —
    ``codec=None`` / ``codec="none"`` keeps the uncompressed trace (and
    the single-value return) bit-for-bit.
    """
    compress = codec is not None and codec.active
    if compress:
        from repro.core import compression as comp
    H = sizes.shape[0]
    onehot = jax.nn.one_hot(assign, M, dtype=jnp.float32)      # (H, M)
    w_dev = sizes.astype(jnp.float32)                          # D_n
    edge_tot = onehot.T @ w_dev                                # (M,) D_{N_m}
    has_dev = edge_tot > 0

    if agg_kernel:
        from repro.kernels.hier_agg.ops import (masked_aggregate,
                                                masked_decode_aggregate)
        # eq. (2): panel built in-kernel from membership rows + sizes
        edge_aggregate = functools.partial(masked_aggregate, onehot.T, w_dev)
        # eq. (3) = the same kernel with an all-ones (1, M) mask over the
        # per-edge cohort sizes D_{N_m} (empty edges weigh 0 already)
        cloud_aggregate = lambda flat: masked_aggregate(  # noqa: E731
            jnp.ones((1, M), jnp.float32), edge_tot, flat)[0]
        # compression path: scales fold into the in-kernel panel, the
        # wire-format q streams into the MXU undecoded
        edge_dec_aggregate = functools.partial(
            masked_decode_aggregate, onehot.T, w_dev)
        cloud_dec_aggregate = lambda sc, q: masked_decode_aggregate(  # noqa: E731
            jnp.ones((1, M), jnp.float32), edge_tot, sc, q)[0]
    else:
        # per-edge normalised device weights: (M, H)
        w_edge = (onehot.T * w_dev[None, :]) \
            / jnp.maximum(edge_tot, 1.0)[:, None]
        w_cloud = jnp.where(has_dev, edge_tot, 0.0)
        w_cloud = w_cloud / jnp.maximum(jnp.sum(w_cloud), 1.0)
        edge_aggregate = lambda flat: w_edge @ flat           # noqa: E731
        cloud_aggregate = lambda flat: w_cloud @ flat         # noqa: E731
        if compress:
            # einsum decode-aggregate oracle: dense decode, then matmul
            edge_dec_aggregate = lambda sc, q: w_edge @ (     # noqa: E731
                comp.decode_rows(codec, q, sc))
            cloud_dec_aggregate = lambda sc, q: w_cloud @ (   # noqa: E731
                comp.decode_rows(codec, q, sc))

    # edge models start from the global model
    edge_params = jax.tree.map(
        lambda g: jnp.broadcast_to(g[None], (M,) + g.shape), global_params)

    if not compress:
        def edge_iter(edge_params, _):
            # each device pulls its edge's model
            dev_params = jax.tree.map(lambda e: jnp.take(e, assign, axis=0),
                                      edge_params)
            dev_params = cohort_local_sgd(apply_fn, dev_params, X, y, mask,
                                          L, lr)
            # (2): weighted average per edge; empty edges keep their model
            # (aggregate in f32, carry the model dtype through the scan)
            def agg(delta, old):
                flat = delta.reshape(H, -1)
                new = edge_aggregate(flat).reshape((M,) + delta.shape[1:])
                keep = has_dev.reshape((M,) + (1,) * (delta.ndim - 1))
                return jnp.where(keep, new, old).astype(old.dtype)
            new_edge = jax.tree.map(agg, dev_params, edge_params)
            return new_edge, None

        edge_params, _ = jax.lax.scan(edge_iter, edge_params, None, length=Q)

        # (3): cloud aggregation, weights D_{N_m} (empty edges weight 0)
        def cloud_agg(e):
            flat = e.reshape(M, -1)
            return cloud_aggregate(flat).reshape(e.shape[1:]).astype(e.dtype)

        return jax.tree.map(cloud_agg, edge_params)

    # ---- compressed path: both uplinks ship encoded deltas; aggregation
    #      runs in delta space (edge' = edge + Σ w·decoded_delta, exactly
    #      eq. (2) for a lossless codec since the weights sum to 1 per
    #      non-empty edge — empty edges get zero weight mass and keep
    #      their model automatically).
    keys = jax.random.split(codec_key, Q + 1)

    def edge_iter_c(carry, k_round):
        edge_params, resid = carry
        pulled = jax.tree.map(lambda e: jnp.take(e, assign, axis=0),
                              edge_params)
        trained = cohort_local_sgd(apply_fn, pulled, X, y, mask, L, lr)
        t_leaves, treedef = jax.tree.flatten(trained)
        p_leaves = jax.tree.leaves(pulled)
        r_leaves = jax.tree.leaves(resid)
        e_leaves = jax.tree.leaves(edge_params)
        ks = jax.random.split(k_round, len(t_leaves))
        new_e, new_r = [], []
        for t, p_, r, e, k in zip(t_leaves, p_leaves, r_leaves, e_leaves,
                                  ks):
            d = (t - p_).reshape(H, -1).astype(jnp.float32)
            q, sc, nr = comp.encode_leaf(codec, k, d, r.reshape(H, -1))
            dm = edge_dec_aggregate(sc, q)                    # (M, p)
            ef = e.reshape(M, -1) + dm
            new_e.append(ef.reshape(e.shape).astype(e.dtype))
            new_r.append(nr.reshape(r.shape))
        return (treedef.unflatten(new_e), treedef.unflatten(new_r)), None

    (edge_params, dev_resid), _ = jax.lax.scan(
        edge_iter_c, (edge_params, dev_resid), keys[:Q])

    # cloud hop: each edge encodes its delta vs the global model (3)
    e_leaves, treedef = jax.tree.flatten(edge_params)
    g_leaves = jax.tree.leaves(global_params)
    r_leaves = jax.tree.leaves(edge_resid)
    ks = jax.random.split(keys[Q], len(e_leaves))
    new_g, new_r = [], []
    for e, g, r, k in zip(e_leaves, g_leaves, r_leaves, ks):
        d = (e.reshape(M, -1) - g.reshape(1, -1)).astype(jnp.float32)
        q, sc, nr = comp.encode_leaf(codec, k, d, r.reshape(M, -1))
        gf = g.reshape(-1) + cloud_dec_aggregate(sc, q)
        new_g.append(gf.reshape(g.shape).astype(g.dtype))
        new_r.append(nr.reshape(r.shape))
    return (treedef.unflatten(new_g), dev_resid, treedef.unflatten(new_r))


@functools.partial(jax.jit, static_argnames=("apply_fn", "M", "L", "Q",
                                             "agg_kernel", "codec"))
def hfl_global_iteration(apply_fn: Callable, global_params, X, y, mask,
                         sizes, assign, *, M: int, L: int, Q: int,
                         lr: float, agg_kernel: bool = False,
                         codec=None, dev_resid=None, edge_resid=None,
                         codec_key=None):
    """Jitted Algorithm 1 — see ``hfl_global_iteration_core``."""
    return hfl_global_iteration_core(apply_fn, global_params, X, y, mask,
                                     sizes, assign, M=M, L=L, Q=Q, lr=lr,
                                     agg_kernel=agg_kernel, codec=codec,
                                     dev_resid=dev_resid,
                                     edge_resid=edge_resid,
                                     codec_key=codec_key)


@functools.partial(jax.jit, static_argnames=("apply_fn",))
def evaluate_accuracy(apply_fn: Callable, params, X_test, y_test):
    logits = apply_fn(params, X_test)
    return jnp.mean((jnp.argmax(logits, axis=-1) == y_test).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("apply_fn",))
def _count_correct(apply_fn: Callable, params, X, y, valid):
    """Correct predictions among rows where ``valid > 0`` (exact int)."""
    logits = apply_fn(params, X)
    hit = (jnp.argmax(logits, axis=-1) == y) & (valid > 0)
    return jnp.sum(hit.astype(jnp.int32))


def evaluate_in_batches(apply_fn, params, X_test, y_test, batch: int = 512):
    """Test accuracy in device-sized batches, host-accumulated.

    Chunks the test set so evaluation never materialises one
    (n_test, ...) activation tensor. The final ragged chunk is padded up
    to the chunk shape with a validity mask instead of compiling a
    second XLA program per (arch, test-set-size) pair; correct counts
    are integers, so the result is the exact sample-weighted accuracy.
    """
    X_test = np.asarray(X_test)
    y_test = np.asarray(y_test)
    n = len(y_test)
    if n == 0:
        return 0.0
    batch = min(batch, n)
    correct = 0
    for i in range(0, n, batch):
        Xc, yc = X_test[i:i + batch], y_test[i:i + batch]
        k = len(yc)
        valid = np.zeros(batch, np.float32)
        valid[:k] = 1.0
        if k < batch:       # pad the ragged tail to the chunk shape
            Xc = np.concatenate(
                [Xc, np.zeros((batch - k, *Xc.shape[1:]), Xc.dtype)])
            yc = np.concatenate([yc, np.zeros(batch - k, yc.dtype)])
        correct += int(_count_correct(apply_fn, params, jnp.asarray(Xc),
                                      jnp.asarray(yc), jnp.asarray(valid)))
    return correct / n
