"""HFL system/cost model — paper §III-B, equations (4)–(14), Table I.

All quantities SI: seconds, joules, hertz, watts, bits.

The wireless network is *simulated* (there is no radio on a TPU pod): the
channel model is the paper's 128.1 + 37.6 log10(d_km) path loss with 8 dB
log-normal shadowing, FDMA uplink (6), and static edge->cloud links
(11)-(12). Everything is vectorised jnp so schedulers/assigners/allocators
can jit/vmap over device populations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import dbm_to_watt


@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Table I."""
    n_devices: int = 100
    n_edges: int = 5
    area_km: float = 1.0
    u_range: tuple = (1e4, 1e5)            # CPU cycles / sample
    d_range: tuple = (400, 700)            # local dataset sizes D_n
    edge_bw_range: tuple = (0.5e6, 3e6)    # B_m  [Hz]
    cloud_bw: float = 10e6                 # B    [Hz]
    p_dbm_range: tuple = (0.0, 23.0)       # device transmit power
    p_edge_dbm: float = 23.0               # edge transmit power
    f_max: float = 2e9                     # max CPU frequency [Hz]
    noise_dbm_hz: float = -174.0           # N0
    alpha: float = 2e-28                   # effective capacitance (α/2 coeff)
    shadow_db: float = 8.0
    L: int = 5                             # local iterations
    Q: int = 5                             # edge iterations
    lam: float = 1.0                       # λ
    model_bits: float = 448e3 * 8          # z (FashionMNIST CNN default)

    @property
    def n0_w_hz(self) -> float:
        return dbm_to_watt(self.noise_dbm_hz)


@dataclasses.dataclass
class Population:
    """A sampled IoT population: device features + channel gains."""
    u: jnp.ndarray          # (N,) cycles/sample
    D: jnp.ndarray          # (N,) samples
    p: jnp.ndarray          # (N,) transmit power [W]
    f_max: jnp.ndarray      # (N,) [Hz]
    g: jnp.ndarray          # (N, M) mean uplink channel gain to each edge
    g_cloud: jnp.ndarray    # (M,) edge->cloud gain
    B_m: jnp.ndarray        # (M,) edge bandwidth [Hz]
    dev_pos: np.ndarray     # (N, 2) km
    edge_pos: np.ndarray    # (M, 2) km

    @property
    def n_devices(self) -> int:
        return self.g.shape[0]

    @property
    def n_edges(self) -> int:
        return self.g.shape[1]

    def features(self) -> jnp.ndarray:
        """(N, M+3) raw per-device feature vectors (ḡ^1..ḡ^M, u, D, p)."""
        return jnp.concatenate(
            [self.g, self.u[:, None], self.D[:, None], self.p[:, None]], axis=1)


@dataclasses.dataclass
class PopulationBatch:
    """E stacked IoT populations — the episode axis of the batched D3QN
    trainer (Alg. 5) and of multi-population assignment searches.

    Every array carries a leading population axis; population ``e`` is
    bitwise-identical to ``sample_population(sp, seeds[e])`` for the
    seeds it was built from (pinned in ``tests/test_cost_model.py``), so
    batched consumers and the per-population serial oracles see the SAME
    worlds.
    """
    u: jnp.ndarray          # (E, N)
    D: jnp.ndarray          # (E, N)
    p: jnp.ndarray          # (E, N)
    f_max: jnp.ndarray      # (E, N)
    g: jnp.ndarray          # (E, N, M)
    g_cloud: jnp.ndarray    # (E, M)
    B_m: jnp.ndarray        # (E, M)
    dev_pos: np.ndarray     # (E, N, 2) km
    edge_pos: np.ndarray    # (E, M, 2) km

    @property
    def n_pops(self) -> int:
        return self.g.shape[0]

    @property
    def n_devices(self) -> int:
        return self.g.shape[1]

    @property
    def n_edges(self) -> int:
        return self.g.shape[2]

    def pop(self, e: int) -> Population:
        """Population ``e`` as a plain (view-sharing) ``Population``."""
        return Population(u=self.u[e], D=self.D[e], p=self.p[e],
                          f_max=self.f_max[e], g=self.g[e],
                          g_cloud=self.g_cloud[e], B_m=self.B_m[e],
                          dev_pos=self.dev_pos[e], edge_pos=self.edge_pos[e])

    def populations(self) -> list:
        return [self.pop(e) for e in range(self.n_pops)]

    def features(self) -> jnp.ndarray:
        """(E, N, M+3) stacked raw per-device feature vectors."""
        return jnp.concatenate(
            [self.g, self.u[..., None], self.D[..., None],
             self.p[..., None]], axis=-1)

    @classmethod
    def stack(cls, pops) -> "PopulationBatch":
        """Stack same-shape ``Population``s along a new leading axis."""
        pops = list(pops)
        return cls(
            u=jnp.stack([p.u for p in pops]),
            D=jnp.stack([p.D for p in pops]),
            p=jnp.stack([p.p for p in pops]),
            f_max=jnp.stack([p.f_max for p in pops]),
            g=jnp.stack([p.g for p in pops]),
            g_cloud=jnp.stack([p.g_cloud for p in pops]),
            B_m=jnp.stack([p.B_m for p in pops]),
            dev_pos=np.stack([p.dev_pos for p in pops]),
            edge_pos=np.stack([p.edge_pos for p in pops]))


def _gain(rng: np.random.Generator, dist_km: np.ndarray, shadow_db: float):
    d = np.maximum(dist_km, 0.01)
    pl_db = 128.1 + 37.6 * np.log10(d)
    shadow = rng.normal(0.0, shadow_db, d.shape)
    return 10 ** (-(pl_db + shadow) / 10.0)


def sample_population(sp: SystemParams, seed: int = 0,
                      d_range: Optional[tuple] = None) -> Population:
    """Devices and edges uniform in the square; cloud at the centre."""
    rng = np.random.default_rng(seed)
    N, M = sp.n_devices, sp.n_edges
    dev_pos = rng.uniform(0, sp.area_km, (N, 2))
    edge_pos = rng.uniform(0, sp.area_km, (M, 2))
    cloud_pos = np.array([sp.area_km / 2, sp.area_km / 2])
    d_ne = np.linalg.norm(dev_pos[:, None] - edge_pos[None], axis=-1)
    d_mc = np.linalg.norm(edge_pos - cloud_pos, axis=-1)
    dr = d_range or sp.d_range
    return Population(
        u=jnp.asarray(rng.uniform(*sp.u_range, N)),
        D=jnp.asarray(rng.integers(dr[0], dr[1] + 1, N).astype(np.float64)),
        p=jnp.asarray(dbm_to_watt(rng.uniform(*sp.p_dbm_range, N))),
        f_max=jnp.full((N,), sp.f_max),
        g=jnp.asarray(_gain(rng, d_ne, sp.shadow_db)),
        g_cloud=jnp.asarray(_gain(rng, d_mc, sp.shadow_db)),
        B_m=jnp.asarray(rng.uniform(*sp.edge_bw_range, M)),
        dev_pos=dev_pos, edge_pos=edge_pos)


def sample_population_batch(sp: SystemParams, n_pops: Optional[int] = None,
                            seed: int = 0, seeds=None,
                            d_range: Optional[tuple] = None
                            ) -> PopulationBatch:
    """E Table-I populations as one stacked ``PopulationBatch``.

    ``seeds`` gives explicit per-population seeds (the batched D3QN
    trainer passes the SAME per-episode seed stream the serial oracle
    draws, so both engines train on identical worlds); otherwise
    ``n_pops`` seeds are derived from the single ``seed`` via
    ``np.random.SeedSequence``. Sampling stays per-seed-equivalent to
    ``sample_population`` — the batching is in the stacked arrays the
    vectorised consumers (``drl_features_batch``,
    ``HFELAssigner.assign_batch``, ``DRLAssigner.assign_batch``) ride,
    not in the host RNG draws.
    """
    if seeds is None:
        if n_pops is None:
            raise ValueError("sample_population_batch needs n_pops or seeds")
        seeds = np.random.SeedSequence(seed).generate_state(n_pops)
    return PopulationBatch.stack(
        sample_population(sp, seed=int(s), d_range=d_range) for s in seeds)


# ------------------------------------------------------- eqs (4)-(8)

def t_cmp(sp: SystemParams, u, D, f):
    """(4): per-edge-iteration computation delay."""
    return sp.L * u * D / f


def e_cmp(sp: SystemParams, u, D, f):
    """(5): per-edge-iteration computation energy."""
    return sp.alpha / 2.0 * sp.L * jnp.square(f) * u * D


def uplink_rate(sp: SystemParams, b, g, p):
    """(6): FDMA uplink rate [bit/s].

    Numerics: computed as ((g*p)/N0) / b — never forming N0*b ~ 1e-15,
    whose square UNDERFLOWS f32 in the division VJP (d(1/y)/dy = -1/y^2)
    and poisons every gradient-based consumer with NaN (resource
    allocator, HFEL; see EXPERIMENTS.md correctness notes).
    """
    b = jnp.maximum(b, 1.0)
    snr = (g * p / sp.n0_w_hz) / b
    return b * jnp.log2(1.0 + snr)


def t_com(sp: SystemParams, b, g, p, model_bits=None):
    """(7)."""
    z = sp.model_bits if model_bits is None else model_bits
    return z / uplink_rate(sp, b, g, p)


def e_com(sp: SystemParams, b, g, p, model_bits=None):
    """(8)."""
    return p * t_com(sp, b, g, p, model_bits)


# ------------------------------------------------------ eqs (9)-(12)

def edge_round_cost(sp: SystemParams, u, D, p, g, b, f, mask,
                    model_bits=None):
    """(9),(10) for one edge: masked devices; returns (T_edge, E_edge)."""
    tc = t_cmp(sp, u, D, f) + t_com(sp, b, g, p, model_bits)
    ec = e_cmp(sp, u, D, f) + e_com(sp, b, g, p, model_bits)
    big = jnp.where(mask, tc, 0.0)
    T_edge = sp.Q * jnp.max(big)
    E_edge = sp.Q * jnp.sum(jnp.where(mask, ec, 0.0))
    return T_edge, E_edge


def cloud_cost(sp: SystemParams, g_cloud_m, model_bits=None):
    """(11),(12) for one edge server."""
    z = sp.model_bits if model_bits is None else model_bits
    p_m = dbm_to_watt(sp.p_edge_dbm)
    rate = sp.cloud_bw * jnp.log2(1.0 + g_cloud_m * p_m /
                                  (sp.n0_w_hz * sp.cloud_bw))
    T_cloud = z / rate
    return T_cloud, p_m * T_cloud


# ------------------------------------------------------ eqs (13)-(14)

def round_cost_gathered(sp: SystemParams, u, D, p, g_sel, g_cloud, assign,
                        b, f, M: int, model_bits=None):
    """(13)/(14) from pre-gathered cohort arrays — traceable core.

    u, D, p, g_sel, b, f: (H,) for the scheduled cohort, with g_sel the
    gain of each device to its *assigned* edge; assign: (H,) edge ids;
    g_cloud: (M,). M must be static under jit (segment count).
    Returns (T_i, E_i, T_m, E_m).

    Per-edge reductions are segment ops over the assignment ids — O(H)
    work and memory instead of the (H, M) one-hot panel, which is what
    keeps cohort cost evaluation O(scheduled) when H is 10^4-10^5.
    Edges with no assigned devices reduce to 0 (the one-hot semantics).
    """
    tc = t_cmp(sp, u, D, f) + t_com(sp, b, g_sel, p, model_bits)
    ec = e_cmp(sp, u, D, f) + e_com(sp, b, g_sel, p, model_bits)
    T_edge = sp.Q * jnp.maximum(
        jax.ops.segment_max(tc, assign, num_segments=M), 0.0)   # (M,)
    E_edge = sp.Q * jax.ops.segment_sum(ec, assign, num_segments=M)
    T_cl, E_cl = cloud_cost(sp, g_cloud, model_bits)
    T_m = T_cl + T_edge
    E_m = E_cl + E_edge
    return jnp.max(T_m), jnp.sum(E_m), T_m, E_m


def round_cost(sp: SystemParams, pop: Population, sched_idx, assign,
               b, f, model_bits=None):
    """One global iteration's (T_i, E_i, per-edge T_m, per-edge E_m).

    sched_idx: (H,) device indices; assign: (H,) edge index per device;
    b, f: (H,) allocations.
    """
    u, D, p = pop.u[sched_idx], pop.D[sched_idx], pop.p[sched_idx]
    g = pop.g[sched_idx, assign]
    return round_cost_gathered(sp, u, D, p, g, pop.g_cloud, assign, b, f,
                               pop.n_edges, model_bits)


def objective(sp: SystemParams, T_i, E_i):
    """Per-round system cost E_i + λ T_i (problem (17))."""
    return E_i + sp.lam * T_i


def round_msg_bits(sp: SystemParams, n_uplink_msgs, n_cloud_msgs,
                   msg_bits=None) -> float:
    """Bits on the air in one global iteration (Fig. 7f/7g accounting).

    ``n_uplink_msgs`` device→edge updates (Q·H synchronously, the number
    of aggregated deliveries asynchronously) plus ``n_cloud_msgs``
    edge→cloud uploads (M), each ``msg_bits`` bits — ``sp.model_bits``
    unless a codec's compressed per-message size is passed
    (:func:`repro.core.compression.message_bits`). The single accounting
    site shared by ``HFLFramework``, ``SweepRunner`` and
    ``AsyncHFLEngine`` so compression is counted exactly once.
    """
    z = sp.model_bits if msg_bits is None else msg_bits
    return float((n_uplink_msgs + n_cloud_msgs) * z)


# ------------------------------------------------- availability traces

@dataclasses.dataclass(frozen=True)
class AvailabilityParams:
    """Intermittent-connectivity knobs for the async engine.

    Devices follow an alternating-renewal (two-state Markov) process:
    exponentially distributed online sessions of mean ``mean_up_s``
    alternate with offline gaps of mean ``mean_down_s``. A
    ``straggler_frac`` fraction of devices has every task latency
    multiplied by ``straggler_scale``; ``jitter_sigma`` adds per-task
    log-normal latency noise (consumed by the engine's host RNG, not the
    trace). The defaults are the degenerate always-on / no-straggler
    setting under which the event-driven engine reproduces the
    synchronous ``round_step`` exactly (the parity oracle recipe in
    ``docs/async.md``).
    """
    p_offline0: float = 0.0                # fraction initially offline
    mean_up_s: float = float("inf")        # mean online session [s]
    mean_down_s: float = 60.0              # mean offline gap [s]
    straggler_frac: float = 0.0            # fraction of slow devices
    straggler_scale: float = 5.0           # their latency multiplier
    jitter_sigma: float = 0.0              # per-task log-normal sigma


def sample_straggler_scales(key, ap: AvailabilityParams, n: int):
    """(n,) per-device latency multipliers — jit/vmap compatible."""
    slow = jax.random.bernoulli(key, ap.straggler_frac, (n,))
    return jnp.where(slow, ap.straggler_scale, 1.0)


def sample_toggle_times(key, ap: AvailabilityParams, n: int,
                        max_toggles: int = 64):
    """Alternating-renewal availability flips — jit/vmap compatible.

    Returns ``(init_up, toggles)``: ``init_up`` (n,) bool initial state,
    ``toggles`` (n, max_toggles) ascending flip times. Holding time j is
    Exp(mean_up) when the device is up during period j, Exp(mean_down)
    when down; an infinite mean (the always-on default) pushes every
    subsequent flip to +inf, so padding and "never flips" coincide.
    """
    k_init, k_dur = jax.random.split(key)
    init_up = jax.random.uniform(k_init, (n,)) >= ap.p_offline0
    j = jnp.arange(max_toggles)[None, :]
    up_during = init_up[:, None] ^ (j % 2 == 1)     # state in period j
    mean = jnp.where(up_during, ap.mean_up_s, ap.mean_down_s)
    dur = jax.random.exponential(k_dur, (n, max_toggles)) * mean
    return init_up, jnp.cumsum(dur, axis=1)


@dataclasses.dataclass
class AvailabilityTrace:
    """Host-side per-device availability trace (async engine input).

    ``toggles[n]`` holds the ascending virtual times at which device n
    flips between online and offline, +inf padded; ``init_up[n]`` is its
    state at t=0 and ``latency_scale[n]`` multiplies every task latency
    (straggler inflation). Build with :func:`sample_availability`, a
    :class:`repro.core.traffic.TrafficGenerator`, or :meth:`always_on`
    (the degenerate parity trace).
    """
    init_up: np.ndarray        # (N,) bool state at t=0
    toggles: np.ndarray        # (N, T) ascending flip times [s], inf-pad
    latency_scale: np.ndarray  # (N,) per-device latency multiplier

    @property
    def n_devices(self) -> int:
        return self.init_up.shape[0]

    @classmethod
    def always_on(cls, n: int) -> "AvailabilityTrace":
        """Every device up forever at unit speed (sync parity trace)."""
        return cls(init_up=np.ones(n, bool),
                   toggles=np.full((n, 1), np.inf),
                   latency_scale=np.ones(n))

    def up_at(self, t: float) -> np.ndarray:
        """(N,) bool availability at virtual time ``t``."""
        flips = (self.toggles <= t).sum(axis=1)
        return self.init_up ^ (flips % 2 == 1)

    def toggles_after(self, n: int, t: float) -> np.ndarray:
        """Device n's finite flip times strictly after ``t``, ascending."""
        row = self.toggles[n]
        return row[(row > t) & np.isfinite(row)]


def sample_availability(ap: AvailabilityParams, n: int, seed: int = 0,
                        max_toggles: int = 64) -> AvailabilityTrace:
    """Sample a host ``AvailabilityTrace`` from the jit-compatible
    samplers — seeded alongside the population so async sweeps replay."""
    k_t, k_s = jax.random.split(jax.random.PRNGKey(seed))
    init_up, toggles = sample_toggle_times(k_t, ap, n, max_toggles)
    scale = sample_straggler_scales(k_s, ap, n)
    return AvailabilityTrace(
        init_up=np.asarray(init_up),
        toggles=np.asarray(toggles, np.float64),
        latency_scale=np.asarray(scale, np.float64))
