"""K-means device clustering (Algorithm 2) + Adjusted Rand Index (eq. 28).

The K-means distance computation routes through the Pallas pairwise-
distance kernel (``repro.kernels.kmeans_dist``) when ``use_kernel=True``
(interpret mode on CPU), with a pure-jnp fallback that is also the
kernel's oracle.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray,
                      use_kernel: bool = False) -> jnp.ndarray:
    """x: (N, D), c: (K, D) -> (N, K) squared euclidean distances."""
    if use_kernel:
        from repro.kernels.kmeans_dist.ops import pairwise_sq_dists as pk
        return pk(x, c)
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    cc = jnp.sum(c * c, axis=1)[None, :]
    return jnp.maximum(xx + cc - 2.0 * (x @ c.T), 0.0)


def _kmeans_pp_init(key, x: jnp.ndarray, k: int,
                    use_kernel: bool = False) -> jnp.ndarray:
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        d = pairwise_sq_dists(x, centers, use_kernel=use_kernel)  # (n, k)
        # only first i centers are valid
        valid = jnp.arange(k) < i
        d = jnp.where(valid[None, :], d, jnp.inf)
        mind = jnp.min(d, axis=1)
        key, ks = jax.random.split(key)
        probs = mind / jnp.maximum(jnp.sum(mind), 1e-12)
        nxt = jax.random.choice(ks, n, p=probs)
        return centers.at[i].set(x[nxt]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, key))
    return centers


@functools.partial(jax.jit, static_argnames=("k", "iters", "use_kernel"))
def kmeans(key, x: jnp.ndarray, k: int, iters: int = 50,
           use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm with kmeans++ init. Returns (labels (N,), centers)."""
    x = x.astype(jnp.float32)
    centers = _kmeans_pp_init(key, x, k, use_kernel=use_kernel)

    def step(carry, _):
        centers = carry
        d = pairwise_sq_dists(x, centers, use_kernel=use_kernel)
        lab = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(lab, k, dtype=jnp.float32)       # (N, k)
        counts = oh.sum(0)
        sums = oh.T @ x
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None],
                        centers)
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    lab = jnp.argmin(pairwise_sq_dists(x, centers, use_kernel=use_kernel), axis=1)
    return lab, centers


def kmeans_best_of(key, x, k: int, restarts: int = 8, iters: int = 50,
                   use_kernel: bool = False):
    """Multiple restarts, keep lowest inertia."""
    best = (None, None, np.inf)
    for r, kk in enumerate(jax.random.split(key, restarts)):
        lab, cen = kmeans(kk, x, k, iters, use_kernel)
        d = pairwise_sq_dists(x, cen, use_kernel=False)
        inertia = float(jnp.sum(jnp.min(d, axis=1)))
        if inertia < best[2]:
            best = (lab, cen, inertia)
    return best[0], best[1]


def adjusted_rand_index(pred: np.ndarray, truth: np.ndarray) -> float:
    """Pair-counting ARI (eq. 28 uses the unadjusted Rand pair counts; we
    report the standard adjusted form as in [42]/sklearn)."""
    pred = np.asarray(pred)
    truth = np.asarray(truth)
    n = len(pred)
    # contingency
    pu, pi = np.unique(pred, return_inverse=True)
    tu, ti = np.unique(truth, return_inverse=True)
    cont = np.zeros((len(pu), len(tu)), dtype=np.int64)
    np.add.at(cont, (pi, ti), 1)
    def c2(v):
        return v * (v - 1) // 2
    sum_ij = c2(cont).sum()
    a = c2(cont.sum(axis=1)).sum()
    b = c2(cont.sum(axis=0)).sum()
    total = c2(n)
    # promote before multiplying: a*b in int64 overflows (silently) once
    # pair counts pass ~3e9, i.e. N ~ 1e5
    exp = float(a) * float(b) / float(total) if total else 0.0
    mx = (a + b) / 2.0
    if mx == exp:
        return 1.0
    return float((sum_ij - exp) / (mx - exp))
