"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, SWA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """q: (B, S, Hq, d), k/v: (B, S, Hkv, d) -> (B, S, Hq, d).

    Materialised-softmax reference in f32.
    """
    B, S, Hq, d = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / jnp.sqrt(d)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= ki <= qi
    if window > 0:
        ok &= ki > qi - window
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
    return out.reshape(B, S, Hq, d).astype(q.dtype)
