"""Pallas TPU kernel: block-streaming flash attention (GQA, causal, SWA).

TPU adaptation of FlashAttention: grid (B, Hq, S/BQ, S/BK) executed with
the key axis innermost; running max / sum / output accumulators live in
VMEM scratch and persist across the key steps (TPU grid iteration is
sequential, which replaces the CUDA thread-block reduction with a
systolic-friendly pipeline). Block shapes are MXU-aligned (BQ=BK=128,
head_dim padded to 128 lanes by the wrapper). GQA is expressed in the
key/value BlockSpec index_map (kv head = q head // G) so keys are never
physically repeated.

Causal + sliding-window masking is applied per (BQ, BK) tile from the
absolute indices; fully-masked tiles still iterate but short-circuit via
``pl.when`` (a production kernel would shrink the grid; see §Perf log).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, n_k: int,
            bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # tile-level reachability (any (q,k) pair in tile unmasked?)
    reachable = True
    if causal:
        reachable = jnp.asarray(k_start <= q_start + bq - 1)
    if window > 0:
        reachable = jnp.logical_and(
            reachable, k_start + bk - 1 > q_start - window)

    @pl.when(reachable if (causal or window > 0) else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (BQ, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (BK, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (BK, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal or window > 0:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            ok = jnp.ones((bq, bk), bool)
            if causal:
                ok &= cols <= rows
            if window > 0:
                ok &= cols > rows - window
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                               # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)                   # (BQ, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / lsum).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, window: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, Hq, d), k/v: (B, S, Hkv, d) -> (B, S, Hq, d)."""
    B, S, Hq, d = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / (d ** 0.5)

    bq = min(bq, S)
    bk = min(bk, S)
    s_pad_q = (-S) % bq
    s_pad_k = (-S) % bk
    d_pad = (-d) % 128
    # layout: (B, H, S, d)
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, s_pad_q), (0, d_pad)))
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad_k), (0, d_pad)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad_k), (0, d_pad)))
    Sq = S + s_pad_q
    Sk = S + s_pad_k
    dp = d + d_pad
    n_k = Sk // bk

    # padded key rows would contribute exp(0-m)=garbage only if they beat the
    # mask; causal masking handles them for ki*bk >= S when causal. For the
    # non-causal case we rely on S % bk == 0 (wrapper asserts).
    if not causal:
        assert s_pad_k == 0, "non-causal path requires S % bk == 0"

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, n_k=n_k,
        bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, Sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dp), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dp), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dp), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dp), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dp), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :S, :d]
    return jnp.moveaxis(out, 1, 2)
