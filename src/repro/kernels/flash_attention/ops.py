"""jit'd public wrapper for flash attention (interpret on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _default_interpret() -> bool:
    # interpret-mode emulation is only needed where Mosaic can't compile:
    # CPU. On TPU (and GPU via mosaic-gpu) run the compiled kernel.
    return jax.default_backend() in ("cpu",)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: bool | None = None, **kw) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=interpret, **kw)
