from repro.kernels.hier_agg.ops import weighted_aggregate  # noqa: F401
