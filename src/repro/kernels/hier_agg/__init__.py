from repro.kernels.hier_agg.ops import (masked_aggregate,  # noqa: F401
                                        weighted_aggregate)
