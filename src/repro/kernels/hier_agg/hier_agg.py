"""Pallas TPU kernels: hierarchical weighted model aggregation (eqs. 2-3).

Edge aggregation is `M edge models = (M x H weight matrix) @ (H devices x
P parameters)`. P is the full flattened model (10^5..10^9), H ≤ a few
hundred — so this is a skinny matmul whose bandwidth cost is streaming the
(H, P) delta matrix through VMEM exactly once. We tile P into 512-lane
blocks, keep the tiny (Mp, Hp) weight panel resident, and emit f32.

Two kernels, each carrying a leading lane axis S with grid (S, P/BP):

* ``weighted_aggregate_batched_pallas`` — caller-supplied (S, M, H)
  weight panels. Per-step VMEM: Hp*BP + Mp*BP + Mp*Hp f32 ≈ 0.3 MiB.
* ``masked_aggregate_batched_pallas`` — the *fused masked-weight*
  variant: takes the raw assignment one-hot / membership mask (S, M, H)
  plus per-device data sizes (S, H) and builds the normalised panel
  ``w = mask·sizes / max(Σ_h mask·sizes, 1)`` INSIDE the kernel, so the
  round engine never materialises ``w_edge`` separately. The panel costs
  Mp·Hp VPU flops per grid step — noise next to the Mp·Hp·BP matmul.
  Cloud aggregation (3) is the same kernel with an all-ones (1, M) mask
  and the per-edge cohort sizes as ``sizes``.

The unbatched entry points (``weighted_aggregate_pallas`` /
``masked_aggregate_pallas``) are the S=1 case of the same kernels — one
kernel body per formula, so tiling/formula changes can't drift between
copies. ``ops.py`` wires the batched kernels up as the
``jax.custom_batching.custom_vmap`` rule of the public ops, so a vmapped
sweep (``core.sweep.SweepRunner``) is ONE kernel launch per round
instead of S per-lane interpret calls.

Empty edges (all-zero mask rows) produce all-zero output rows — callers
keep their ``jnp.where(has_dev, new, old)`` fixup outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP = 512
SUB = 8      # f32 sublane multiple


def _pad2(a, s0, s1):
    """Pad the trailing two dims up to multiples of (s0, s1)."""
    pads = [(0, 0)] * (a.ndim - 2)
    pads += [(0, (-a.shape[-2]) % s0), (0, (-a.shape[-1]) % s1)]
    return jnp.pad(a, pads)


# ------------------------------------------------------- plain weights

def _kernel_batched(w_ref, d_ref, out_ref):
    w = w_ref[0].astype(jnp.float32)              # (Mp, Hp)
    d = d_ref[0].astype(jnp.float32)              # (Hp, BP)
    out_ref[0] = jax.lax.dot_general(
        w, d, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate_batched_pallas(weights: jnp.ndarray,
                                      deltas: jnp.ndarray,
                                      interpret: bool = True) -> jnp.ndarray:
    """weights: (S, M, H); deltas: (S, H, P) -> (S, M, P) f32, one
    launch with grid (S, P/BP)."""
    S, M, H = weights.shape
    S2, H2, P = deltas.shape
    assert S == S2 and H == H2
    wp = _pad2(weights, SUB, SUB)
    dp = _pad2(deltas, SUB, BP)
    Mp, Hp = wp.shape[1:]
    Pp = dp.shape[2]
    out = pl.pallas_call(
        _kernel_batched,
        grid=(S, Pp // BP),
        in_specs=[
            pl.BlockSpec((1, Mp, Hp), lambda s, p: (s, 0, 0)),
            pl.BlockSpec((1, Hp, BP), lambda s, p: (s, 0, p)),
        ],
        out_specs=pl.BlockSpec((1, Mp, BP), lambda s, p: (s, 0, p)),
        out_shape=jax.ShapeDtypeStruct((S, Mp, Pp), jnp.float32),
        interpret=interpret,
    )(wp, dp)
    return out[:, :M, :P]


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate_pallas(weights: jnp.ndarray, deltas: jnp.ndarray,
                              interpret: bool = True) -> jnp.ndarray:
    """weights: (M, H); deltas: (H, P) -> (M, P) f32 — the S=1 lane of
    the batched kernel (one kernel body to maintain)."""
    return weighted_aggregate_batched_pallas(weights[None], deltas[None],
                                             interpret=interpret)[0]


# ---------------------------------------------------- fused masked weights

def _masked_kernel_batched(m_ref, s_ref, d_ref, out_ref):
    m = m_ref[0].astype(jnp.float32)              # (Mp, Hp) membership
    s = s_ref[0].astype(jnp.float32)              # (SUB, Hp) sizes row 0
    w = m * s[0][None, :]                         # (Mp, Hp) mask·D_n
    tot = jnp.sum(w, axis=1, keepdims=True)       # (Mp, 1)  D_{N_m}
    w = w / jnp.maximum(tot, 1.0)
    d = d_ref[0].astype(jnp.float32)              # (Hp, BP)
    out_ref[0] = jax.lax.dot_general(
        w, d, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_aggregate_batched_pallas(mask: jnp.ndarray, sizes: jnp.ndarray,
                                    deltas: jnp.ndarray,
                                    interpret: bool = True) -> jnp.ndarray:
    """Fused masked-weight aggregation over a lane axis.

    mask: (S, M, H) membership rows; sizes: (S, H) per-device data
    sizes; deltas: (S, H, P) -> (S, M, P) f32 in ONE launch with grid
    (S, P/BP) — the ``custom_vmap`` target that keeps vmapped sweeps at
    one kernel call per round. Output row m is
    ``Σ_h mask[m,h]·sizes[h]·deltas[h] / max(Σ_h mask[m,h]·sizes[h], 1)``
    — eq. (2) per edge, and eq. (3) with mask=ones((1, M)), sizes=D_{N_m}.
    """
    S, M, H = mask.shape
    assert sizes.shape == (S, H) and deltas.shape[:2] == (S, H)
    P = deltas.shape[2]
    mp = _pad2(mask, SUB, SUB)
    sp = _pad2(jnp.broadcast_to(sizes[:, None, :], (S, SUB, H)), SUB, SUB)
    dp = _pad2(deltas, SUB, BP)
    Mp, Hp = mp.shape[1:]
    Pp = dp.shape[2]
    out = pl.pallas_call(
        _masked_kernel_batched,
        grid=(S, Pp // BP),
        in_specs=[
            pl.BlockSpec((1, Mp, Hp), lambda s, p: (s, 0, 0)),
            pl.BlockSpec((1, SUB, Hp), lambda s, p: (s, 0, 0)),
            pl.BlockSpec((1, Hp, BP), lambda s, p: (s, 0, p)),
        ],
        out_specs=pl.BlockSpec((1, Mp, BP), lambda s, p: (s, 0, p)),
        out_shape=jax.ShapeDtypeStruct((S, Mp, Pp), jnp.float32),
        interpret=interpret,
    )(mp, sp, dp)
    return out[:, :M, :P]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_aggregate_pallas(mask: jnp.ndarray, sizes: jnp.ndarray,
                            deltas: jnp.ndarray,
                            interpret: bool = True) -> jnp.ndarray:
    """mask: (M, H); sizes: (H,); deltas: (H, P) -> (M, P) f32 — the S=1
    lane of the batched masked kernel (one kernel body to maintain)."""
    return masked_aggregate_batched_pallas(mask[None], sizes[None],
                                           deltas[None],
                                           interpret=interpret)[0]


# ------------------------------------------- fused masked decode-aggregate

def _masked_dec_kernel_batched(m_ref, s_ref, sc_ref, q_ref, out_ref):
    m = m_ref[0].astype(jnp.float32)              # (Mp, Hp) membership
    s = s_ref[0].astype(jnp.float32)              # (SUB, Hp) sizes row 0
    sc = sc_ref[0].astype(jnp.float32)            # (SUB, Hp) scales row 0
    w = m * s[0][None, :]                         # (Mp, Hp) mask·D_n
    tot = jnp.sum(w, axis=1, keepdims=True)       # (Mp, 1)  D_{N_m}
    # decode scale folded into the weight panel: the quantized update
    # matrix goes into the MXU as-is, no dense decoded (Hp, BP) temp.
    w = (w / jnp.maximum(tot, 1.0)) * sc[0][None, :]
    q = q_ref[0].astype(jnp.float32)              # (Hp, BP) wire dtype
    out_ref[0] = jax.lax.dot_general(
        w, q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def _q_sublane(dtype) -> int:
    """Sublane multiple for the quantized operand's dtype (the int8/bf16
    min-tile constraint is tighter than the f32 SUB)."""
    return {1: 32, 2: 16}.get(jnp.dtype(dtype).itemsize, SUB)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_decode_aggregate_batched_pallas(mask: jnp.ndarray,
                                           sizes: jnp.ndarray,
                                           scales: jnp.ndarray,
                                           q: jnp.ndarray,
                                           interpret: bool = True
                                           ) -> jnp.ndarray:
    """Masked-weight aggregation of *encoded* updates over a lane axis.

    mask: (S, M, H); sizes: (S, H); scales: (S, H) per-message decode
    scales; q: (S, H, P) quantized updates (int8 / bf16 / masked f32)
    -> (S, M, P) f32 rows
    ``Σ_h mask[m,h]·sizes[h]·scales[h]·q[h] / max(Σ_h mask[m,h]·sizes[h], 1)``
    in ONE launch with grid (S, P/BP). This is eq. (2)/(3) applied to
    decoded deltas ``scales[h]·q[h]`` with the decode folded into the
    in-kernel weight panel — the dense decoded update matrix is never
    materialised; the MXU streams the wire-format q directly.
    """
    S, M, H = mask.shape
    assert sizes.shape == (S, H) and scales.shape == (S, H)
    assert q.shape[:2] == (S, H)
    P = q.shape[2]
    hsub = max(SUB, _q_sublane(q.dtype))          # shared H padding
    mp = _pad2(mask, SUB, hsub)
    sp = _pad2(jnp.broadcast_to(sizes[:, None, :], (S, SUB, H)), SUB, hsub)
    scp = _pad2(jnp.broadcast_to(scales[:, None, :], (S, SUB, H)), SUB, hsub)
    qp = _pad2(q, hsub, BP)
    Mp, Hp = mp.shape[1:]
    Pp = qp.shape[2]
    out = pl.pallas_call(
        _masked_dec_kernel_batched,
        grid=(S, Pp // BP),
        in_specs=[
            pl.BlockSpec((1, Mp, Hp), lambda s, p: (s, 0, 0)),
            pl.BlockSpec((1, SUB, Hp), lambda s, p: (s, 0, 0)),
            pl.BlockSpec((1, SUB, Hp), lambda s, p: (s, 0, 0)),
            pl.BlockSpec((1, Hp, BP), lambda s, p: (s, 0, p)),
        ],
        out_specs=pl.BlockSpec((1, Mp, BP), lambda s, p: (s, 0, p)),
        out_shape=jax.ShapeDtypeStruct((S, Mp, Pp), jnp.float32),
        interpret=interpret,
    )(mp, sp, scp, qp)
    return out[:, :M, :P]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_decode_aggregate_pallas(mask: jnp.ndarray, sizes: jnp.ndarray,
                                   scales: jnp.ndarray, q: jnp.ndarray,
                                   interpret: bool = True) -> jnp.ndarray:
    """mask: (M, H); sizes: (H,); scales: (H,); q: (H, P) -> (M, P) f32
    — the S=1 lane of the batched decode-aggregate kernel."""
    return masked_decode_aggregate_batched_pallas(
        mask[None], sizes[None], scales[None], q[None],
        interpret=interpret)[0]
