"""Pallas TPU kernel: hierarchical weighted model aggregation (eqs. 2-3).

Edge aggregation is `M edge models = (M x H weight matrix) @ (H devices x
P parameters)`. P is the full flattened model (10^5..10^9), H ≤ a few
hundred — so this is a skinny matmul whose bandwidth cost is streaming the
(H, P) delta matrix through VMEM exactly once. We tile P into 512-lane
blocks, keep the tiny (Mp, Hp) weight panel resident, and emit f32.

Grid: (P/BP,). Per-step VMEM: Hp*BP + Mp*BP + Mp*Hp f32 ≈ 0.3 MiB.
The same kernel serves cloud aggregation (M=1 row of edge weights).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP = 512
SUB = 8      # f32 sublane multiple


def _kernel(w_ref, d_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)            # (Mp, Hp)
    d = d_ref[...].astype(jnp.float32)            # (Hp, BP)
    out_ref[...] = jax.lax.dot_general(
        w, d, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate_pallas(weights: jnp.ndarray, deltas: jnp.ndarray,
                              interpret: bool = True) -> jnp.ndarray:
    M, H = weights.shape
    H2, P = deltas.shape
    assert H == H2
    wp = jnp.pad(weights, ((0, (-M) % SUB), (0, (-H) % SUB)))
    dp = jnp.pad(deltas, ((0, (-H) % SUB), (0, (-P) % BP)))
    Mp, Hp = wp.shape
    Pp = dp.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(Pp // BP,),
        in_specs=[
            pl.BlockSpec((Mp, Hp), lambda p: (0, 0)),
            pl.BlockSpec((Hp, BP), lambda p: (0, p)),
        ],
        out_specs=pl.BlockSpec((Mp, BP), lambda p: (0, p)),
        out_shape=jax.ShapeDtypeStruct((Mp, Pp), jnp.float32),
        interpret=interpret,
    )(wp, dp)
    return out[:M, :P]
