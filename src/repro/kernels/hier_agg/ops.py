"""Public wrappers for the weighted-aggregation kernels.

``weighted_aggregate`` / ``masked_aggregate`` are wrapped in
``jax.custom_batching.custom_vmap`` whose rule dispatches to the
lane-batched kernels (grid ``(S, P/BP)``): a ``jax.vmap`` over sweep
lanes — e.g. ``core.sweep.sweep_round`` vmapping ``round_step_core`` —
lowers to ONE kernel launch per round instead of falling back to S
per-lane interpret calls. Unbatched operands (e.g. the constant all-ones
cloud mask) are broadcast along the lane axis inside the rule.

Interpret mode is resolved at trace time from the backend (interpret
everywhere but TPU), mirroring the kmeans_dist kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hier_agg.hier_agg import (
    masked_aggregate_batched_pallas, masked_aggregate_pallas,
    masked_decode_aggregate_batched_pallas, masked_decode_aggregate_pallas,
    weighted_aggregate_batched_pallas, weighted_aggregate_pallas)


def _default_interpret() -> bool:
    # interpret-mode emulation is only needed where Mosaic can't compile:
    # CPU. On TPU (and GPU via mosaic-gpu) run the compiled kernel.
    return jax.default_backend() in ("cpu",)


def _bcast(x, batched, axis_size):
    return x if batched else jnp.broadcast_to(x[None], (axis_size,) + x.shape)


@jax.custom_batching.custom_vmap
def _weighted_cv(weights: jnp.ndarray, deltas: jnp.ndarray) -> jnp.ndarray:
    return weighted_aggregate_pallas(weights, deltas,
                                     interpret=_default_interpret())


@_weighted_cv.def_vmap
def _weighted_cv_rule(axis_size, in_batched, weights, deltas):
    weights = _bcast(weights, in_batched[0], axis_size)
    deltas = _bcast(deltas, in_batched[1], axis_size)
    out = weighted_aggregate_batched_pallas(weights, deltas,
                                            interpret=_default_interpret())
    return out, True


@jax.custom_batching.custom_vmap
def _masked_cv(mask: jnp.ndarray, sizes: jnp.ndarray,
               deltas: jnp.ndarray) -> jnp.ndarray:
    return masked_aggregate_pallas(mask, sizes, deltas,
                                   interpret=_default_interpret())


@_masked_cv.def_vmap
def _masked_cv_rule(axis_size, in_batched, mask, sizes, deltas):
    mask = _bcast(mask, in_batched[0], axis_size)
    sizes = _bcast(sizes, in_batched[1], axis_size)
    deltas = _bcast(deltas, in_batched[2], axis_size)
    out = masked_aggregate_batched_pallas(mask, sizes, deltas,
                                          interpret=_default_interpret())
    return out, True


@jax.custom_batching.custom_vmap
def _masked_dec_cv(mask: jnp.ndarray, sizes: jnp.ndarray,
                   scales: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    return masked_decode_aggregate_pallas(mask, sizes, scales, q,
                                          interpret=_default_interpret())


@_masked_dec_cv.def_vmap
def _masked_dec_cv_rule(axis_size, in_batched, mask, sizes, scales, q):
    mask = _bcast(mask, in_batched[0], axis_size)
    sizes = _bcast(sizes, in_batched[1], axis_size)
    scales = _bcast(scales, in_batched[2], axis_size)
    q = _bcast(q, in_batched[3], axis_size)
    out = masked_decode_aggregate_batched_pallas(
        mask, sizes, scales, q, interpret=_default_interpret())
    return out, True


def weighted_aggregate(weights: jnp.ndarray, deltas: jnp.ndarray,
                       interpret: bool | None = None) -> jnp.ndarray:
    """weights: (M, H) panel (rows pre-normalised); deltas: (H, P) ->
    (M, P) f32. vmap-aware: batched calls hit the (S, P/BP) kernel."""
    if interpret is None:
        return _weighted_cv(weights, deltas)
    return weighted_aggregate_pallas(weights, deltas, interpret=interpret)


def masked_aggregate(mask: jnp.ndarray, sizes: jnp.ndarray,
                     deltas: jnp.ndarray,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Fused masked-weight aggregation (weight panel built in-kernel).

    mask: (M, H) membership rows; sizes: (H,); deltas: (H, P) -> (M, P)
    f32 rows ``Σ mask·sizes·deltas / max(Σ mask·sizes, 1)``. Empty rows
    (all-zero mask) come back all-zero. vmap-aware like
    ``weighted_aggregate``.
    """
    if interpret is None:
        return _masked_cv(mask, sizes, deltas)
    return masked_aggregate_pallas(mask, sizes, deltas, interpret=interpret)


def masked_decode_aggregate(mask: jnp.ndarray, sizes: jnp.ndarray,
                            scales: jnp.ndarray, q: jnp.ndarray,
                            interpret: bool | None = None) -> jnp.ndarray:
    """Masked-weight aggregation of *encoded* updates (compression path).

    mask: (M, H) membership rows; sizes: (H,); scales: (H,) per-message
    decode scales; q: (H, P) wire-format updates (int8/bf16/masked f32)
    -> (M, P) f32 rows ``Σ mask·sizes·scales·q / max(Σ mask·sizes, 1)``.
    The decode scale is folded into the in-kernel weight panel so the
    dense decoded update matrix never exists. vmap-aware like
    ``masked_aggregate``.
    """
    if interpret is None:
        return _masked_dec_cv(mask, sizes, scales, q)
    return masked_decode_aggregate_pallas(mask, sizes, scales, q,
                                          interpret=interpret)


def aggregate_pytrees(weights: jnp.ndarray, device_params,
                      interpret: bool | None = None):
    """weights: (M, H); device_params: pytree with leading device axis H.
    Returns pytree with leading axis M (edge models)."""
    def leaf(x):
        H = x.shape[0]
        flat = x.reshape(H, -1)
        out = weighted_aggregate(weights, flat, interpret=interpret)
        return out.reshape((weights.shape[0],) + x.shape[1:]).astype(x.dtype)
    return jax.tree.map(leaf, device_params)
