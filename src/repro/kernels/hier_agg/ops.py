"""jit'd public wrapper for the weighted-aggregation kernel + a pytree
convenience used by the HFL trainer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hier_agg.hier_agg import weighted_aggregate_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def weighted_aggregate(weights: jnp.ndarray, deltas: jnp.ndarray,
                       interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return weighted_aggregate_pallas(weights, deltas, interpret=interpret)


def aggregate_pytrees(weights: jnp.ndarray, device_params,
                      interpret: bool | None = None):
    """weights: (M, H); device_params: pytree with leading device axis H.
    Returns pytree with leading axis M (edge models)."""
    def leaf(x):
        H = x.shape[0]
        flat = x.reshape(H, -1)
        out = weighted_aggregate(weights, flat, interpret=interpret)
        return out.reshape((weights.shape[0],) + x.shape[1:]).astype(x.dtype)
    return jax.tree.map(leaf, device_params)
