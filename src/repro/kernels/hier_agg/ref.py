"""Pure-jnp oracles for the hierarchical weighted-aggregation kernels."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_aggregate_ref(weights: jnp.ndarray,
                           deltas: jnp.ndarray) -> jnp.ndarray:
    """weights: (M, H) aggregation weights (rows already normalised);
    deltas: (H, P) flattened per-device model updates -> (M, P) f32."""
    return weights.astype(jnp.float32) @ deltas.astype(jnp.float32)


def masked_aggregate_ref(mask: jnp.ndarray, sizes: jnp.ndarray,
                         deltas: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused masked-weight variant: builds the normalised
    (M, H) panel as the einsum path does, then matmuls. mask: (M, H);
    sizes: (H,); deltas: (H, P) -> (M, P) f32."""
    w = mask.astype(jnp.float32) * sizes.astype(jnp.float32)[None, :]
    tot = jnp.sum(w, axis=1, keepdims=True)
    w = w / jnp.maximum(tot, 1.0)
    return w @ deltas.astype(jnp.float32)


def masked_decode_aggregate_ref(mask: jnp.ndarray, sizes: jnp.ndarray,
                                scales: jnp.ndarray,
                                q: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the decode-aggregate variant: the einsum path decodes
    the wire-format updates densely (``scales[:, None] * q``) and then
    masked-aggregates. mask: (M, H); sizes: (H,); scales: (H,);
    q: (H, P) -> (M, P) f32."""
    dec = q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]
    return masked_aggregate_ref(mask, sizes, dec)
