"""Pure-jnp oracle for the hierarchical weighted-aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_aggregate_ref(weights: jnp.ndarray,
                           deltas: jnp.ndarray) -> jnp.ndarray:
    """weights: (M, H) aggregation weights (rows already normalised);
    deltas: (H, P) flattened per-device model updates -> (M, P) f32."""
    return weights.astype(jnp.float32) @ deltas.astype(jnp.float32)
