"""jit'd public wrapper for the K-means pairwise-distance kernel.

On CPU (this container) the kernel body executes in interpret mode; on a
real TPU set ``interpret=False`` (the default flips on TPU platforms).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_dist.kmeans_dist import pairwise_sq_dists_pallas


def _default_interpret() -> bool:
    # interpret-mode emulation is only needed where Mosaic can't compile:
    # CPU. On TPU (and GPU via mosaic-gpu) run the compiled kernel.
    return jax.default_backend() in ("cpu",)


def pairwise_sq_dists(x: jnp.ndarray, c: jnp.ndarray,
                      interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = _default_interpret()
    return pairwise_sq_dists_pallas(x, c, interpret=interpret)
