from repro.kernels.kmeans_dist.ops import pairwise_sq_dists  # noqa: F401
