"""Pure-jnp oracle for the pairwise squared-distance kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """x: (N, D), c: (K, D) -> (N, K) squared euclidean distances, f32."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    cc = jnp.sum(c * c, axis=1)[None, :]
    return jnp.maximum(xx + cc - 2.0 * (x @ c.T), 0.0)
