"""Pallas TPU kernel: pairwise squared distances for K-means (Algorithm 2).

The clustering hot spot is N devices x P auxiliary-model weights against
K centroids. TPU adaptation: the ||x||^2 - 2 x.c + ||c||^2 expansion turns
the distance matrix into one MXU matmul plus row/col norms; we tile N into
MXU-aligned 128-row blocks held in VMEM, tile the centroid axis into
128-wide panels, and stream 512-wide feature blocks when P is large.

Grid: (N/BN, K/BK, P/BP). The feature axis is the *reduction* axis,
iterated innermost with an f32 VMEM scratch accumulator; each (BN, BK)
output block is finalised (clamped at 0) on its last feature step. The
blocked K axis means clustering at N=1e5 never materialises a monolithic
(N, Kp) panel per grid step — only (BN, BK) tiles live in VMEM.

VMEM budget per step: BN*BP + BK*BP + 2*BN*BK f32 ≈ 0.5 MiB « 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BN = 128     # device rows per block  (MXU lane-aligned)
BP = 512     # feature columns per reduction step
BK = 128     # centroid columns per block

def _kernel(x_ref, c_ref, out_ref, acc_ref, *, n_p_blocks: int):
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)           # (BN, BP)
    c = c_ref[...].astype(jnp.float32)           # (BK, BP)
    xx = jnp.sum(x * x, axis=1, keepdims=True)   # (BN, 1)
    cc = jnp.sum(c * c, axis=1)[None, :]         # (1, BK)
    acc_ref[...] += xx + cc - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pi == n_p_blocks - 1)
    def _done():
        out_ref[...] = jnp.maximum(acc_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_sq_dists_pallas(x: jnp.ndarray, c: jnp.ndarray,
                             interpret: bool = True) -> jnp.ndarray:
    """x: (N, P), c: (K, P) -> (N, K) f32. Pads to tile multiples."""
    N, P = x.shape
    K = c.shape[0]
    xp = jnp.pad(x, ((0, (-N) % BN), (0, (-P) % BP)))
    cp = jnp.pad(c, ((0, (-K) % BK), (0, (-P) % BP)))
    Np, Pp = xp.shape
    Kp = cp.shape[0]
    n_p_blocks = Pp // BP

    out = pl.pallas_call(
        functools.partial(_kernel, n_p_blocks=n_p_blocks),
        grid=(Np // BN, Kp // BK, n_p_blocks),
        in_specs=[
            pl.BlockSpec((BN, BP), lambda i, j, p: (i, p)),
            pl.BlockSpec((BK, BP), lambda i, j, p: (j, p)),
        ],
        out_specs=pl.BlockSpec((BN, BK), lambda i, j, p: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BN, BK), jnp.float32)],
        interpret=interpret,
    )(xp, cp)
    return out[:N, :K]
