"""Modality-frontend STUBS (the one allowed carve-out).

VLM (InternVL2): the InternViT-6B vision encoder + MLP projector is not
reproduced; ``vision_patch_embeds`` emits patch embeddings with the exact
interface contract (B, n_patches, d_model) the language model consumes.

Audio (MusicGen): the EnCodec conv codec is not reproduced;
``encodec_tokens`` emits K parallel codebook token streams (B, S, K) in
[0, vocab). The decoder-only transformer over these tokens IS implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def vision_patch_embeds(key, batch: int, cfg: ModelConfig,
                        dtype=jnp.float32) -> jnp.ndarray:
    """Stub ViT output: (B, cfg.n_prefix_embeds, d_model)."""
    return (jax.random.normal(key, (batch, cfg.n_prefix_embeds, cfg.d_model))
            * 0.02).astype(dtype)


def encodec_tokens(key, batch: int, seq: int, cfg: ModelConfig) -> jnp.ndarray:
    """Stub EnCodec tokens: (B, S, n_codebooks) int32."""
    return jax.random.randint(key, (batch, seq, cfg.n_codebooks), 0,
                              cfg.vocab_size, dtype=jnp.int32)
