"""Core layer primitives (pure-functional: params are plain pytrees)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def he_normal(key, shape, fan_in=None, dtype=jnp.float32):
    """He/Kaiming init [41] — used for both the HFL CNN and transformers."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = math.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    return he_normal(key, (d_in, d_out), fan_in=d_in, dtype=dtype)


def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


# ---------------------------------------------------------------- RMSNorm

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


# ------------------------------------------------------------------ RoPE

def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray):
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, hd//2) -> broadcast over batch
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, hd//2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------- SwiGLU

def mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(params, x):
    g = jax.nn.silu(x @ params["w_gate"])
    u = x @ params["w_up"]
    return (g * u) @ params["w_down"]


# ---------------------------------------------------- depthwise causal conv

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B, S, C), w: (C, W).

    If `state` (B, W-1, C) is given, runs in streaming mode (decode):
    returns (y, new_state) with y: (B, S, C).
    """
    B, S, C = x.shape
    W = w.shape[1]
    if state is None:
        pad = jnp.zeros((B, W - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    # gather W shifted views and contract: y[t] = sum_j w[:, j] * xp[t+j]
    ys = 0.0
    for j in range(W):
        ys = ys + xp[:, j:j + S, :] * w[:, j]
    new_state = xp[:, S:, :] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return ys, new_state
