"""Mixture-of-Experts MLP with fixed-capacity scatter dispatch.

Design notes (TPU adaptation):
  * no (T, E, C) one-hot combine tensor — positions are computed with a
    (T*k, E) cumsum and tokens are scattered into an (E, C, D) buffer,
    which shards cleanly over the `model` mesh axis (expert parallelism);
  * grouped expert matmuls are plain einsums over the expert-sharded
    buffer so the MXU sees dense [C, D] x [D, F] tiles;
  * fixed capacity C = ceil(T * top_k / E * capacity_factor) with
    token-order priority dropping (standard GShard/Switch semantics);
  * router computed in f32; load-balance aux loss per Switch-Transformer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.parallel.sharder import NOOP, Sharder
from repro.utils import ceil_div


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    kr, kg, ku, kd = jax.random.split(key, 4)
    D, F, E = cfg.d_model, cfg.d_ff, m.num_experts
    def e_init(k, a, b):
        ks = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, a, b, dtype) for kk in ks])
    return {
        "router": dense_init(kr, D, E, jnp.float32),
        "w_gate": e_init(kg, D, F),
        "w_up": e_init(ku, D, F),
        "w_down": e_init(kd, F, D),
    }


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = ceil_div(n_tokens * m.top_k, m.num_experts)
    return max(4, int(c * m.capacity_factor))


def moe_apply(params, x, cfg: ModelConfig, *,
              sharder: Sharder = NOOP) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Dispatch is chunked per batch shard (`sharder.data_chunks`): each data
    shard fills its own capacity slice, so the (gd, E, C_local, D) expert
    buffer shards over BOTH `data` (gd) and `model` (E) and the grouped
    matmuls divide by the full chip count. With a single global capacity
    buffer the expert compute only divided by the model axis — measured
    16x FLOP inflation on qwen3 train_4k (§Perf hillclimb pair 3). Token
    rows are dispatched with an int-index scatter + row GATHER; a row
    scatter-add lowers to a dense one-hot matmul (further ~13x).
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    gd = getattr(sharder, "data_chunks", 1)
    if T % gd != 0 or T // gd < 1:
        gd = 1
    Tl = T // gd
    C = moe_capacity(Tl, cfg)

    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32)) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)                      # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch eq. 4-6)
    me = jnp.mean(probs, axis=0)                                  # (E,)
    one = jax.nn.one_hot(top_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one, axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- per-chunk dispatch positions (local capacity per data shard)
    flat_e = top_idx.reshape(gd, Tl * k)                          # (gd, Tl*k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)               # (gd,Tl*k,E)
    pos_all = jnp.cumsum(oh, axis=1) - 1
    my_pos = jnp.take_along_axis(pos_all, flat_e[..., None],
                                 axis=2)[..., 0]                  # (gd, Tl*k)
    keep = (my_pos < C)
    safe_pos = jnp.where(keep, my_pos, C - 1)

    tok_idx = jnp.broadcast_to((jnp.arange(Tl * k) // k)[None],
                               (gd, Tl * k)).astype(jnp.int32)
    safe_e = jnp.where(keep, flat_e, E)                           # OOB=drop

    def fill_slots(e_idx, pos, tok):
        base = jnp.full((E, C), Tl, jnp.int32)                    # Tl = zero row
        return base.at[e_idx, pos].set(tok, mode="drop")

    slot_tok = jax.vmap(fill_slots)(safe_e, safe_pos, tok_idx)    # (gd, E, C)
    xg = xf.reshape(gd, Tl, D)
    x_ext = jnp.concatenate([xg, jnp.zeros((gd, 1, D), xf.dtype)], axis=1)
    buf = jax.vmap(lambda xe, st: xe[st])(x_ext, slot_tok)       # (gd,E,C,D)
    buf = sharder.act(buf, "moe_buffer")

    # ---- expert compute (E over `model`, gd over `data`)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf,
                               params["w_gate"].astype(buf.dtype)))
    g = sharder.act(g, "moe_hidden")
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(buf.dtype))
    u = sharder.act(u, "moe_hidden")
    y = jnp.einsum("gecf,efd->gecd", g * u,
                   params["w_down"].astype(buf.dtype))
    y = sharder.act(y, "moe_buffer")

    # ---- combine (per-chunk gather)
    out_per = jax.vmap(lambda ye, e, p: ye[e, p])(
        y, flat_e, safe_pos)                                      # (gd,Tl*k,D)
    out_per = out_per * keep[..., None].astype(y.dtype)
    w_flat = top_w.reshape(gd, Tl * k, 1).astype(y.dtype)
    out = (out_per * w_flat).reshape(gd, Tl, k, D).sum(axis=2)
    return out.reshape(B, S, D), aux
