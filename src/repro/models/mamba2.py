"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block in JAX.

Two execution paths, both pure jnp:
  * ``ssd_chunked``  — the blocked SSD algorithm (intra-chunk quadratic
    "attention-like" term + inter-chunk recurrence via lax.scan over
    chunks). This is the train/prefill path; chunk size is MXU-friendly.
  * ``ssd_recurrent_step`` — O(1)-state single-token decode update.

A naive full-sequence recurrence (``ssd_reference``) is kept for tests:
chunked and reference must agree to ~1e-4 in f32.

Layout conventions:
  x        (B, S, H, P)      P = head_dim
  dt       (B, S, H)
  A_log    (H,)              A = -exp(A_log) (scalar per head, SSD)
  B_, C_   (B, S, G, N)      N = d_state, G groups broadcast to heads
  state    (B, H, P, N)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, dense_init, rmsnorm, rmsnorm_init
from repro.parallel.sharder import NOOP, Sharder


# --------------------------------------------------------------- params

def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32):
    """Per-segment projections instead of one fused in_proj.

    The fused (D, 2*di+2*G*N+nh) projection's split boundaries do not
    align with 16-way model-axis shards, forcing XLA to replicate the
    whole matmul (~9x FLOP waste measured on mamba2-2.7b train_4k; §Perf
    hillclimb). Separate z/x/B/C/dt projections shard cleanly, and the
    depthwise conv distributes over the concatenation, so the math is
    identical.
    """
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.n_heads(D)
    gn = s.n_groups * s.d_state
    k1, k2, k3, k4, k5, k6, k7, k8, k9 = jax.random.split(key, 9)
    def conv(k, c):
        return (jax.random.normal(k, (c, s.conv_width)) * 0.1).astype(dtype)
    return {
        "wz": dense_init(k1, D, di, dtype),
        "wx": dense_init(k2, D, di, dtype),
        "wb": dense_init(k3, D, gn, dtype),
        "wc": dense_init(k4, D, gn, dtype),
        "wdt": dense_init(k5, D, nh, dtype),
        "conv_x": conv(k6, di),
        "conv_b": conv(k7, gn),
        "conv_c": conv(k8, gn),
        "A_log": jnp.zeros((nh,), jnp.float32),           # A = -1 at init
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -1.0, jnp.float32),    # softplus(-1) ~ 0.31
        "gate_norm": rmsnorm_init(di),
        "out_proj": dense_init(k9, di, D, dtype),
    }


def _project(params, hidden):
    """hidden @ {wz,wx,wb,wc,wdt} -> (z, x, B_, C_, dt)."""
    dt_ = hidden.dtype
    return (hidden @ params["wz"].astype(dt_),
            hidden @ params["wx"].astype(dt_),
            hidden @ params["wb"].astype(dt_),
            hidden @ params["wc"].astype(dt_),
            hidden @ params["wdt"].astype(dt_))


# ----------------------------------------------------------- SSD math

def ssd_reference(x, dt, A, B_, C_, chunk=None):
    """Naive per-timestep recurrence (oracle). Shapes as module docstring."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    G = B_.shape[2]
    Bh = jnp.repeat(B_, H // G, axis=2)   # (B,S,H,N)
    Ch = jnp.repeat(C_, H // G, axis=2)
    dA = jnp.exp(dt * A)                  # (B,S,H)

    def step(state, inp):
        xt, dtt, dAt, Bt, Ct = inp
        state = dAt[..., None, None] * state + (dtt[..., None, None] * xt[..., None]) * Bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dA, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1)         # (B,S,H,P)


def ssd_chunked(x, dt, A, B_, C_, chunk: int, sharder: Sharder = NOOP):
    """Blocked SSD. Returns (B,S,H,P) in f32.

    The head axis H is explicitly sharding-constrained on every chunked
    intermediate: without the constraints XLA replicates the (cs, cs, H)
    decay/score tensors across the model axis (measured 64.8 GB/device
    temp for mamba2-2.7b train_4k; see EXPERIMENTS.md §Perf iteration 1).
    """
    Bsz, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    nc, cs = S // chunk, chunk
    f32 = jnp.float32

    xr = x.reshape(Bsz, nc, cs, H, P).astype(f32)
    xr = sharder.act(xr, "ssm_chunk_x")
    dtr = dt.reshape(Bsz, nc, cs, H).astype(f32)
    Br = jnp.repeat(B_, H // G, axis=2).reshape(Bsz, nc, cs, H, N).astype(f32)
    Cr = jnp.repeat(C_, H // G, axis=2).reshape(Bsz, nc, cs, H, N).astype(f32)
    Br = sharder.act(Br, "ssm_chunk_bc")
    Cr = sharder.act(Cr, "ssm_chunk_bc")

    dA = dtr * A                                            # (B,nc,cs,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                            # inclusive cumsum
    cum = sharder.act(cum, "ssm_chunk_cum")
    xdt = xr * dtr[..., None]

    # ---- intra-chunk (quadratic within chunk)
    # L[i,j] = exp(cum[i] - cum[j]) for i >= j  (i attends to j<=i)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,i,j,H)
    li = jnp.arange(cs)
    causal = (li[:, None] >= li[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    L = sharder.act(L, "ssm_chunk_ij")
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br) * L
    scores = sharder.act(scores, "ssm_chunk_ij")
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # ---- per-chunk terminal states
    # S_c = sum_j exp(cum[last] - cum[j]) * B_j (x dt)_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,cs,H)
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", decay_to_end, Br, xdt)

    # ---- inter-chunk recurrence over chunks
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    def step(carry, inp):
        st, dec = inp
        new = dec[:, :, None, None] * carry + st
        return new, carry                                   # emit state BEFORE this chunk

    init = jnp.zeros((Bsz, H, P, N), f32)
    _, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    # ---- inter-chunk contribution: y[i] += exp(cum[i]) * C_i . state_prev
    in_decay = jnp.exp(cum)                                 # decay from chunk start
    y_inter = jnp.einsum("bcih,bcihn,bchpn->bcihp", in_decay, Cr, prev_states)

    return (y_intra + y_inter).reshape(Bsz, S, H, P)


def ssd_recurrent_step(state, x, dt, A, B_, C_):
    """Single-token update. x:(B,H,P) dt:(B,H) B_/C_:(B,G,N) state:(B,H,P,N)."""
    H = x.shape[1]
    G = B_.shape[1]
    Bh = jnp.repeat(B_, H // G, axis=1)
    Ch = jnp.repeat(C_, H // G, axis=1)
    dA = jnp.exp(dt * A)
    state = dA[..., None, None] * state + (dt[..., None, None] * x[..., None]) * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return state, y


# ------------------------------------------------------------ full block

def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state   # [x | B | C] stream
    return {
        "ssm": jnp.zeros((batch, s.n_heads(cfg.d_model), s.head_dim,
                          s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def mamba2_forward(params, hidden, cfg: ModelConfig, *,
                   sharder: Sharder = NOOP) -> jnp.ndarray:
    """Full-sequence forward. hidden: (B, S, D)."""
    s = cfg.ssm
    B, S, D = hidden.shape
    di = s.d_inner(D)
    nh = s.n_heads(D)
    z, x, B_, C_, dt = _project(params, hidden)
    x, _ = causal_conv1d(jax.nn.silu(x), params["conv_x"].astype(x.dtype))
    B_, _ = causal_conv1d(jax.nn.silu(B_), params["conv_b"].astype(x.dtype))
    C_, _ = causal_conv1d(jax.nn.silu(C_), params["conv_c"].astype(x.dtype))
    x = x.reshape(B, S, nh, s.head_dim)
    x = sharder.act(x, "ssm_heads")
    B_ = B_.reshape(B, S, s.n_groups, s.d_state)
    C_ = C_.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y = ssd_chunked(x, dt, A, B_, C_, min(s.chunk, S), sharder=sharder)
    y = y + params["D_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(hidden.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(y.dtype)
    return sharder.act(out, "act_resid")


def mamba2_decode(params, hidden, cache, cfg: ModelConfig, *,
                  sharder: Sharder = NOOP) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. hidden: (B, 1, D)."""
    s = cfg.ssm
    B, S1, D = hidden.shape
    di = s.d_inner(D)
    nh = s.n_heads(D)
    gn = s.n_groups * s.d_state
    z, x, B_, C_, dt = _project(params, hidden)
    # one shared rolling conv state over the [x|B|C] stream
    st_x, st_b, st_c = jnp.split(cache["conv"], [di, di + gn], axis=-1)
    x, st_x = causal_conv1d(jax.nn.silu(x), params["conv_x"].astype(x.dtype),
                            state=st_x)
    B_, st_b = causal_conv1d(jax.nn.silu(B_), params["conv_b"].astype(x.dtype),
                             state=st_b)
    C_, st_c = causal_conv1d(jax.nn.silu(C_), params["conv_c"].astype(x.dtype),
                             state=st_c)
    conv_state = jnp.concatenate([st_x, st_b, st_c], axis=-1)
    x = x[:, 0].reshape(B, nh, s.head_dim)
    B_ = B_.reshape(B, s.n_groups, s.d_state)
    C_ = C_.reshape(B, s.n_groups, s.d_state)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    state, y = ssd_recurrent_step(cache["ssm"], x.astype(jnp.float32), dt1, A, B_, C_)
    y = y + params["D_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(hidden.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(y.dtype)
    out = sharder.act(out, "act_resid")
    return out, {"ssm": state, "conv": conv_state}
