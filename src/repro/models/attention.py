"""Grouped-query attention with RoPE, full/sliding-window masks, KV cache.

Supports:
  * train/prefill forward (causal or banded-causal for SWA),
  * single-token decode against a full or rolling (SWA) KV cache,
  * GQA with any n_kv_heads <= n_heads (kv replicated across groups).

The XLA einsum path is the default; the Pallas flash-attention kernel in
``repro.kernels.flash_attention`` is selectable via ``impl='pallas'`` for
the non-cached forward (validated in interpret mode on CPU).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rope_freqs
from repro.parallel.sharder import NOOP, Sharder

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, hd = cfg.d_model, cfg.hd
    return {
        "wq": dense_init(kq, D, cfg.n_heads * hd, dtype),
        "wk": dense_init(kk, D, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv, D, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, D, dtype),
    }


def _causal_mask(S: int, window: int, offset: int = 0) -> jnp.ndarray:
    """(S, S) additive mask; window>0 adds the sliding-window band."""
    q = jnp.arange(S)[:, None] + offset
    k = jnp.arange(S)[None, :] + offset
    ok = k <= q
    if window > 0:
        ok &= k > q - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """q: (B,S,Hq,hd) k/v: (B,T,Hkv,hd); mask additive (S,T) or (B,1,1,S,T)."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq * hd)


CHUNK_Q_THRESHOLD = 8192   # chunk queries above this sequence length
CHUNK_Q = 2048


def _chunked_sdpa(q, k, v, window: int, sharder: Sharder,
                  score_kind: str = "attn_scores_seq",
                  unroll: bool = False) -> jnp.ndarray:
    """Query-chunked causal attention (flash-style, XLA level).

    Bounds the materialised score tile to (B, H, CHUNK_Q, S) — with the
    kv-sequence axis sharding-constrained over `model` ("attn_scores"),
    so 32k prefill fits even for archs whose head count cannot shard
    16-way (musicgen 24H, scout 40H: unchunked scores were 424/706
    GB/device; see §Perf).
    """
    B, S, Hq, hd = q.shape
    bq = min(CHUNK_Q, S)
    assert S % bq == 0, (S, bq)
    nq = S // bq
    qs = jnp.moveaxis(q.reshape(B, nq, bq, Hq, hd), 1, 0)   # (nq,B,bq,H,hd)
    kT = k
    vT = v

    def chunk(carry, inp):
        i, qc = inp
        rows = i * bq + jnp.arange(bq)[:, None]
        cols = jnp.arange(S)[None, :]
        ok = cols <= rows
        if window > 0:
            ok &= cols > rows - window
        mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        G = Hq // kT.shape[2]
        qg = qc.reshape(B, bq, kT.shape[2], G, hd)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kT).astype(jnp.float32)
        scores = scores / jnp.sqrt(hd).astype(jnp.float32)
        scores = sharder.act(scores, score_kind)
        scores = scores + mask
        probs = jax.nn.softmax(scores, axis=-1).astype(vT.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, vT)
        return carry, out.reshape(B, bq, Hq * hd)

    _, outs = jax.lax.scan(chunk, 0, (jnp.arange(nq), qs),
                           unroll=nq if unroll else 1)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq * hd)


def attn_forward(params, x, cfg: ModelConfig, *, pos_offset: int = 0,
                 sharder: Sharder = NOOP, impl: str = "xla") -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill)."""
    B, S, D = x.shape
    hd = cfg.hd
    wq, wk, wv = (params[n].astype(x.dtype) for n in ("wq", "wk", "wv"))
    q = (x @ wq).reshape(B, S, cfg.n_heads, hd)
    k = (x @ wk).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ wv).reshape(B, S, cfg.n_kv_heads, hd)
    q = sharder.act(q, "act_heads")
    k = sharder.act(k, "act_kv_heads")
    v = sharder.act(v, "act_kv_heads")
    pos = jnp.arange(S) + pos_offset
    cos, sin = rope_freqs(hd, cfg.rope_theta, pos)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True,
                              window=cfg.sliding_window).reshape(B, S, -1)
    elif S > CHUNK_Q_THRESHOLD:
        # long prefill: bound score memory via query chunking
        G = cfg.n_heads // cfg.n_kv_heads
        if G > 1 and cfg.tp_strategy == "heads":
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            k = sharder.act(k, "act_heads")
            v = sharder.act(v, "act_heads")
        kind = ("attn_scores_heads" if cfg.tp_strategy == "heads"
                else "attn_scores_seq")
        out = _chunked_sdpa(q, k, v, cfg.sliding_window, sharder,
                            score_kind=kind, unroll=cfg.unroll_layers)
    else:
        # repeat kv to full q heads BEFORE the score einsum: with kv_heads
        # (2/4/8) < the 16-way model axis, the grouped (B,kv,G,S,T) score
        # layout cannot shard 16-way and XLA falls back to "involuntary
        # full rematerialization" (replicated S x T scores). Repeated keys
        # are head-sharded like q, so scores shard (B, Hq/16, S, T).
        G = cfg.n_heads // cfg.n_kv_heads
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            k = sharder.act(k, "act_heads")
            v = sharder.act(v, "act_heads")
        mask = _causal_mask(S, cfg.sliding_window, 0)
        out = _sdpa(q, k, v, mask)
    out = out @ params["wo"].astype(out.dtype)
    return sharder.act(out, "act_resid")


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Rolling cache if cfg.sliding_window>0 (slots = window), else max_len."""
    slots = cfg.sliding_window if cfg.sliding_window > 0 else max_len
    slots = min(slots, max_len)
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
    }


def attn_decode(params, x, cache, pos, cfg: ModelConfig, *,
                sharder: Sharder = NOOP) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current position).

    RoPE is applied at write time, so cached keys store rotated values.
    """
    B, S1, D = x.shape
    assert S1 == 1
    hd = cfg.hd
    slots = cache["k"].shape[1]
    wq, wk, wv = (params[n].astype(x.dtype) for n in ("wq", "wk", "wv"))
    q = (x @ wq).reshape(B, 1, cfg.n_heads, hd)
    k = (x @ wk).reshape(B, 1, cfg.n_kv_heads, hd)
    v = (x @ wv).reshape(B, 1, cfg.n_kv_heads, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, pos[None])
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])
    slot = jnp.mod(pos, slots)
    new_k = jax.lax.dynamic_update_index_in_dim(cache["k"], k[:, 0].astype(cache["k"].dtype), slot, 1)
    new_v = jax.lax.dynamic_update_index_in_dim(cache["v"], v[:, 0].astype(cache["v"].dtype), slot, 1)
    # validity of each slot: token position stored in slot s is the largest
    # p <= pos with p % slots == s; valid iff p > pos - slots and p >= 0.
    s_idx = jnp.arange(slots)
    newest = pos - jnp.mod(pos - s_idx, slots)      # position held by slot s
    valid = newest >= jnp.maximum(0, pos - slots + 1)
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # (1, slots)
    out = _sdpa(q, new_k, new_v, mask)
    out = out @ params["wo"].astype(out.dtype)
    out = sharder.act(out, "act_resid")
    return out, {"k": new_k, "v": new_v}
