"""ModelSpec — the payload contract every HFL engine trains over.

The paper's scheduling/assignment machinery is payload-agnostic (eqs.
(6)/(9)/(12) only read ``model_bits``), so the engines bind to a spec
instead of a concrete model:

* ``init_fn(key, fed) -> params`` — model init shaped by the federated
  task (input geometry, ``fed.n_classes``).
* ``apply_fn(params, X) -> logits`` — hashable and equality-stable: the
  engines pass it as a static jit argument, so the SAME object must come
  back for a given arch (``configs.registry.get_hfl_spec`` caches specs)
  or jit caches fragment. Any input adaptation (e.g. casting padded
  token tensors back to int32) is folded into ``apply_fn`` so the
  engines' call sites stay identical across payloads.
* ``eval_fn(params, X_test, y_test) -> float`` — chunked test accuracy.
* ``mini_init_fn`` / ``mini_apply_fn`` / ``mini_preprocess_fn`` — the
  IKC auxiliary model ξ and its input crop (Table I/II clustering path);
  ``mini_preprocess_fn(X, key)`` maps the padded (N, Dmax, ...) cohort
  tensor to the clustering inputs, splitting ``key`` per device.

``cnn_spec()`` reproduces the pre-spec engines' construction bit for bit
(same ``cnn.cnn_apply`` function object, same key-split order), which is
what keeps ``arch="hfl-cnn"`` on the engines' existing jit cache
entries — pinned by ``tests/test_model_zoo.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.core.hfl import evaluate_in_batches
from repro.models import cnn
from repro.models import seq_classifier as seqc


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    arch: str                       # registry id (``hfl-cnn``, ...)
    family: str                     # cnn | dense | moe | ssm | hybrid
    init_fn: Callable               # (key, fed) -> params
    apply_fn: Callable              # (params, X) -> logits (static-jit-safe)
    eval_fn: Callable               # (params, X_test, y_test) -> accuracy
    mini_init_fn: Callable          # (key, fed) -> aux params (IKC ξ)
    mini_apply_fn: Callable         # (params, crop) -> logits
    mini_preprocess_fn: Callable    # (X (N, Dmax, ...), key) -> crops


# ------------------------------------------------------------- hfl-cnn

def _cnn_init(key, fed):
    return cnn.cnn_init(key, fed.X_test.shape[1:3], fed.X_test.shape[3],
                        fed.n_classes)


def _cnn_mini_init(key, fed):
    return cnn.mini_init(key, fed.n_classes)


def _cnn_mini_preprocess(X, key):
    """Channel 0, random 10x10 crop per device (IKC preprocessing)."""
    return jax.vmap(cnn.mini_preprocess)(
        X[:, :, :, :, :1], jax.random.split(key, X.shape[0]))


def cnn_spec() -> ModelSpec:
    return ModelSpec(
        arch="hfl-cnn", family="cnn",
        init_fn=_cnn_init, apply_fn=cnn.cnn_apply,
        eval_fn=functools.partial(evaluate_in_batches, cnn.cnn_apply),
        mini_init_fn=_cnn_mini_init, mini_apply_fn=cnn.mini_apply,
        mini_preprocess_fn=_cnn_mini_preprocess)


# ----------------------------------------------- registry decoder archs

@dataclasses.dataclass(frozen=True)
class _SeqInit:
    cfg: ModelConfig

    def __call__(self, key, fed):
        return seqc.seq_cls_init(key, self.cfg, fed.n_classes)


@dataclasses.dataclass(frozen=True)
class _SeqMiniInit:
    vocab: int

    def __call__(self, key, fed):
        return seqc.seq_mini_init(key, self.vocab, fed.n_classes)


def _seq_mini_preprocess(X, key):
    return jax.vmap(seqc.seq_mini_preprocess)(
        X, jax.random.split(key, X.shape[0]))


def seq_spec(arch: str, cfg: ModelConfig) -> ModelSpec:
    """Sequence-classification spec over a registry ``ModelConfig``."""
    apply_fn = seqc.SeqClassifierApply(cfg)
    return ModelSpec(
        arch=arch, family=cfg.family,
        init_fn=_SeqInit(cfg), apply_fn=apply_fn,
        eval_fn=functools.partial(evaluate_in_batches, apply_fn),
        mini_init_fn=_SeqMiniInit(cfg.vocab_size),
        mini_apply_fn=seqc.seq_mini_apply,
        mini_preprocess_fn=_seq_mini_preprocess)
