"""Sequence-classification heads over the unified decoder backbone.

The HFL engines train ``apply_fn(params, X) -> logits`` classifiers; this
module wraps ``models/transformer.py`` — one ``ModelConfig`` covering the
dense / MoE / SSM / hybrid registry families — as such a classifier:
embed int tokens, run the super-block backbone, RMS-norm, mean-pool over
the sequence, project to ``n_classes``. The MoE router aux-loss is
dropped (smoke-scale payloads; the engines' loss is plain softmax
cross-entropy).

``SeqClassifierApply`` is a frozen dataclass callable so it is hashable
and equality-stable — the engines pass ``apply_fn`` as a static jit
argument, and two specs built from the same ``ModelConfig`` must hit the
same compiled program.

The IKC auxiliary path gets a sequence mini model ξ (embed + mean-pool +
linear, ~10 KB like the paper's image mini model) trained on a random
``SEQ_MINI_CROP``-token crop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as transformer_lib
from repro.models.layers import embed_init, he_normal, rmsnorm

SEQ_MINI_DIM = 8        # mini-model embedding width
SEQ_MINI_CROP = 8       # tokens kept by the IKC preprocessing crop


def seq_cls_init(key, cfg: ModelConfig, n_classes: int) -> Dict:
    """Backbone params + ``cls_head`` (the lm_head is dropped)."""
    k_backbone, k_head = jax.random.split(key)
    params = transformer_lib.init(k_backbone, cfg)
    params.pop("lm_head", None)
    params["cls_head"] = he_normal(k_head, (cfg.d_model, n_classes),
                                   fan_in=cfg.d_model)
    return params


@dataclasses.dataclass(frozen=True)
class SeqClassifierApply:
    """``(params, tokens (B, S)) -> logits (B, n_classes)``.

    Tokens are cast to int32 on entry so float-padded cohort tensors
    (``pad_device_data`` zero rows) index the embedding safely.
    """
    cfg: ModelConfig

    def __call__(self, params, tokens) -> jnp.ndarray:
        cfg = self.cfg
        tok = tokens.astype(jnp.int32)
        x = jnp.take(params["embed"], tok, axis=0).astype(cfg.compute_dtype)
        x, _aux = transformer_lib.backbone(params, x, cfg)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        pooled = x.mean(axis=1).astype(jnp.float32)
        return pooled @ params["cls_head"]


def seq_mini_init(key, vocab: int, n_classes: int,
                  d_model: int = SEQ_MINI_DIM) -> Dict:
    """Mini model ξ for IKC clustering: embed + mean-pool + linear."""
    k1, k2 = jax.random.split(key)
    return {
        "embed": embed_init(k1, vocab, d_model),
        "fc": he_normal(k2, (d_model, n_classes), fan_in=d_model),
    }


def seq_mini_apply(params, tokens) -> jnp.ndarray:
    """tokens: (B, S_crop) -> logits (B, n_classes)."""
    x = jnp.take(params["embed"], tokens.astype(jnp.int32), axis=0)
    return x.mean(axis=1) @ params["fc"]


def seq_mini_preprocess(tokens, key) -> jnp.ndarray:
    """IKC preprocessing: random contiguous ``SEQ_MINI_CROP``-token crop.

    tokens: (B, S) one device's padded samples -> (B, min(S, crop)).
    """
    B, S = tokens.shape
    crop = min(S, SEQ_MINI_CROP)
    off = jax.random.randint(key, (), 0, S - crop + 1)
    return jax.lax.dynamic_slice(tokens, (0, off), (B, crop))
