"""Unified decoder-only model covering all assigned families.

One ``ModelConfig`` drives construction of dense / MoE / SSM / hybrid /
VLM / audio decoders from the same code path:

  * layers are grouped into *super-blocks* of ``SB = lcm(hybrid_period,
    moe.every)`` distinct layer templates; parameters for template j are
    stacked across the n_layers/SB blocks and the whole stack is executed
    with ``lax.scan`` (small HLO, scan-friendly remat);
  * mixed precision: parameters live in f32 (optimizer-owned), compute is
    cast to ``cfg.dtype`` (bf16 on TPU);
  * VLM ("vlm") prepends ``n_prefix_embeds`` dense patch embeddings from
    the (stubbed) vision frontend; audio ("audio") embeds K codebooks and
    predicts K vocab heads (EnCodec-token decoder, MusicGen-style).

API:
  init(key, cfg)                          -> params pytree
  forward(params, batch, cfg, ...)        -> (logits, aux_loss)
  init_cache(cfg, batch, max_len, ...)    -> decode cache pytree
  decode(params, tokens, cache, pos, ...) -> (logits, new_cache)
  loss_fn(params, batch, cfg, ...)        -> (scalar, metrics)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models.layers import dense_init, embed_init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init
from repro.parallel.sharder import NOOP, Sharder


def super_block(cfg: ModelConfig) -> int:
    p = cfg.hybrid_period if cfg.hybrid_period > 0 else 1
    e = cfg.moe.every if cfg.is_moe else 1
    sb = math.lcm(p, e)
    assert cfg.n_layers % sb == 0, (cfg.name, cfg.n_layers, sb)
    return sb


# ------------------------------------------------------------------ init

def _layer_init(key, cfg: ModelConfig, idx: int, dtype):
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model)}
    if cfg.layer_kind(idx) == "attn":
        p["mix"] = attn.attn_init(k1, cfg, dtype)
    else:
        p["mix"] = m2.mamba2_init(k1, cfg, dtype)
    kind = cfg.mlp_kind(idx)
    if kind != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        if kind == "moe":
            p["mlp"] = moe_lib.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    sb = super_block(cfg)
    nb = cfg.n_layers // sb
    keys = jax.random.split(key, 3 + sb)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size * max(1, cfg.n_codebooks),
                            cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], cfg.d_model, cfg.vocab_size * max(1, cfg.n_codebooks), dtype)
    blocks = []
    for j in range(sb):
        bkeys = jax.random.split(keys[3 + j], nb)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_layer_init(bkeys[b], cfg, j, dtype) for b in range(nb)])
        blocks.append(stacked)
    params["blocks"] = blocks
    return params


# ----------------------------------------------------------------- embed

def _embed_tokens(params, tokens, cfg: ModelConfig):
    """tokens: (B, S) or (B, S, K) for audio -> (B, S, D) in compute dtype."""
    emb = params["embed"]
    if cfg.family == "audio" and cfg.n_codebooks > 1:
        # codebook k uses rows [k*V, (k+1)*V)
        offs = jnp.arange(cfg.n_codebooks) * cfg.vocab_size
        x = jnp.take(emb, tokens + offs[None, None, :], axis=0).sum(axis=2)
    else:
        x = jnp.take(emb, tokens, axis=0)
    return x.astype(cfg.compute_dtype)


def _lm_head(params, x, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w.astype(x.dtype)
    if cfg.family == "audio" and cfg.n_codebooks > 1:
        B, S, _ = logits.shape
        return logits.reshape(B, S, cfg.n_codebooks, cfg.vocab_size)
    return logits


# --------------------------------------------------------------- forward

def _apply_layer(p, x, cfg: ModelConfig, idx: int, sharder: Sharder, impl: str):
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if cfg.layer_kind(idx) == "attn":
        h = attn.attn_forward(p["mix"], h, cfg, sharder=sharder, impl=impl)
    else:
        h = m2.mamba2_forward(p["mix"], h, cfg, sharder=sharder)
    x = x + h
    kind = cfg.mlp_kind(idx)
    if kind != "none":
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            h, aux = moe_lib.moe_apply(p["mlp"], h, cfg, sharder=sharder)
        else:
            g = {k: v.astype(h.dtype) for k, v in p["mlp"].items()}
            h = mlp_apply(g, h)
        x = x + h
    return sharder.act(x, "act_resid"), aux


def backbone(params, x, cfg: ModelConfig, *, sharder: Sharder = NOOP,
             impl: str = "xla") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) embedded input -> (hidden, total_aux_loss)."""
    sb = super_block(cfg)

    def block_body(carry, block_params):
        h, aux = carry
        for j in range(sb):
            # [nested per-layer remat inside the super-block was tried for
            #  jamba's 73.5 GB/dev peak and REFUTED: +30% flops, +2 GB —
            #  the peak is not intra-block recompute; §Perf iteration 6]
            h, a = _apply_layer(block_params[j], h, cfg, j, sharder, impl)
            aux = aux + a
        return (h, aux), None

    body = block_body
    if cfg.remat:
        body = jax.checkpoint(block_body, prevent_cse=False)
    nb = cfg.n_layers // sb
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"],
                               unroll=nb if cfg.unroll_layers else 1)
    return x, aux


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig, *,
            sharder: Sharder = NOOP, impl: str = "xla"):
    """Train/prefill forward. batch: tokens (+ prefix_embeds for vlm/audio).

    Returns (logits over token positions, aux_loss).
    """
    x = _embed_tokens(params, batch["tokens"], cfg)
    n_prefix = 0
    if cfg.n_prefix_embeds > 0 and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(x.dtype)
        n_prefix = pre.shape[1]
        x = jnp.concatenate([pre, x], axis=1)
    x = sharder.act(x, "act_resid")
    x, aux = backbone(params, x, cfg, sharder=sharder, impl=impl)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_prefix > 0:
        x = x[:, n_prefix:]
    logits = _lm_head(params, x, cfg)
    return sharder.act(logits, "logits"), aux


# ----------------------------------------------------------------- loss

def loss_fn(params, batch, cfg: ModelConfig, *, sharder: Sharder = NOOP,
            impl: str = "xla"):
    logits, aux = forward(params, batch, cfg, sharder=sharder, impl=impl)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold).mean()
    total = nll
    if cfg.is_moe:
        total = total + cfg.moe.router_aux_weight * aux
    return total, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------- decode

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Any:
    """Per-super-block-position stacked caches (for scan over blocks)."""
    dtype = dtype or cfg.compute_dtype
    sb = super_block(cfg)
    nb = cfg.n_layers // sb
    caches = []
    for j in range(sb):
        if cfg.layer_kind(j) == "attn":
            one = attn.init_kv_cache(cfg, batch, max_len, dtype)
        else:
            one = m2.init_ssm_cache(cfg, batch, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (nb,) + x.shape), one))
    return caches


def decode(params, tokens, cache, pos, cfg: ModelConfig, *,
           sharder: Sharder = NOOP):
    """One decode step. tokens: (B, 1) or (B, 1, K); pos: scalar int32."""
    x = _embed_tokens(params, tokens, cfg)
    x = sharder.act(x, "act_resid_decode")
    sb = super_block(cfg)

    def block_body(h, scanned):
        block_params, block_cache = scanned
        new_caches = []
        for j in range(sb):
            p = block_params[j]
            c = block_cache[j]
            hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
            if cfg.layer_kind(j) == "attn":
                hn, nc = attn.attn_decode(p["mix"], hn, c, pos, cfg, sharder=sharder)
            else:
                hn, nc = m2.mamba2_decode(p["mix"], hn, c, cfg, sharder=sharder)
            h = h + hn
            kind = cfg.mlp_kind(j)
            if kind != "none":
                hn = rmsnorm(p["norm2"], h, cfg.norm_eps)
                if kind == "moe":
                    hn, _ = moe_lib.moe_apply(p["mlp"], hn, cfg, sharder=sharder)
                else:
                    g = {k: v.astype(hn.dtype) for k, v in p["mlp"].items()}
                    hn = mlp_apply(g, hn)
                h = h + hn
            new_caches.append(nc)
        return h, new_caches

    nb = cfg.n_layers // sb
    x, new_cache = jax.lax.scan(block_body, x, (params["blocks"], cache),
                                unroll=nb if cfg.unroll_layers else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    return logits, new_cache
