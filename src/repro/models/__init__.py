from repro.models import attention, cnn, frontend, layers, mamba2, moe, transformer  # noqa: F401
