"""The paper's HFL models.

* ``cnn``  — the HFL task model (Section VI): two 5x5 conv layers with 15
  and 28 output channels, each followed by 2x2 max-pool, then two linear
  layers. Hidden width is chosen so the f32 parameter size matches the
  paper's Table I message sizes (z = 448 KB FashionMNIST / 882 KB CIFAR-10).
* ``mini`` — the IKC mini model ξ: one 2x2 conv (+2x2 max-pool) and one
  linear layer over a 1x10x10 crop; ~10 KB as in Table I.

Everything is NHWC; init is He-normal [41] as in the paper.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import he_normal


def _conv(x, w):
    """VALID 2D conv via im2col + GEMM.

    On XLA:CPU the direct lax.conv path (and especially the
    SelectAndScatter backward of reduce_window pooling) is ~10x slower
    than a patches-matmul formulation; the HFL trainer calls this inside
    a vmapped Q*L-deep scan, so it is the simulation's hot loop.
    """
    kh, kw, ci, co = w.shape
    B, H, W, C = x.shape
    oh, ow = H - kh + 1, W - kw + 1
    patches = jnp.stack([x[:, i:i + oh, j:j + ow, :]
                         for i in range(kh) for j in range(kw)], axis=3)
    return patches.reshape(B, oh, ow, kh * kw * C) @ w.reshape(kh * kw * ci, co)


def _maxpool2(x):
    """2x2/2 max pool via reshape (dims must be even — they are for both
    dataset geometries); avoids reduce_window's slow CPU backward."""
    B, H, W, C = x.shape
    x = x[:, :H // 2 * 2, :W // 2 * 2, :]   # truncate odd edges (VALID)
    return x.reshape(B, H // 2, 2, W // 2, 2, C).max(axis=(2, 4))


def cnn_init(key, image_hw: Tuple[int, int], channels: int, n_classes: int = 10,
             hidden: int | None = None) -> Dict:
    """hidden=None picks the paper-size width (226 for 28x28x1, 294 for 32x32x3)."""
    H, W = image_hw
    if hidden is None:
        hidden = 226 if channels == 1 else 294
    h1, w1 = (H - 4) // 2, (W - 4) // 2
    h2, w2 = (h1 - 4) // 2, (w1 - 4) // 2
    flat = h2 * w2 * 28
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "conv1": he_normal(k1, (5, 5, channels, 15), fan_in=5 * 5 * channels),
        "conv2": he_normal(k2, (5, 5, 15, 28), fan_in=5 * 5 * 15),
        "fc1": he_normal(k3, (flat, hidden), fan_in=flat),
        "fc2": he_normal(k4, (hidden, n_classes), fan_in=hidden),
    }


def cnn_apply(params, x) -> jnp.ndarray:
    """x: (B, H, W, C) in [0,1] -> logits (B, n_classes)."""
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv1"])))
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    return x @ params["fc2"]


def mini_init(key, n_classes: int = 10, channels_out: int = 10) -> Dict:
    """Mini model ξ on a 1x10x10 crop: 2x2 conv -> 2x2 pool -> linear."""
    k1, k2 = jax.random.split(key)
    flat = 4 * 4 * channels_out  # (10-1)//2 = 4 after VALID conv + pool
    return {
        "conv": he_normal(k1, (2, 2, 1, channels_out), fan_in=4),
        "fc": he_normal(k2, (flat, n_classes), fan_in=flat),
    }


def mini_apply(params, x) -> jnp.ndarray:
    """x: (B, 10, 10, 1) single-channel random crop."""
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv"])))
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc"]


def mini_preprocess(images: jnp.ndarray, key) -> jnp.ndarray:
    """IKC preprocessing: keep channel 0, random-crop to 10x10."""
    B, H, W, C = images.shape
    kx, ky = jax.random.split(key)
    ox = jax.random.randint(kx, (), 0, H - 10 + 1)
    oy = jax.random.randint(ky, (), 0, W - 10 + 1)
    crop = jax.lax.dynamic_slice(images, (0, ox, oy, 0), (B, 10, 10, 1))
    return crop


def softmax_xent(logits, labels) -> jnp.ndarray:
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - gold).mean()
