from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, adam, adafactor, clip_by_global_norm)
from repro.optim.schedules import constant, cosine, warmup_cosine  # noqa: F401
