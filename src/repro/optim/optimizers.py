"""Minimal optax-style optimizers built in-repo (offline substrate).

Each optimizer is an ``Optimizer(init, update)`` pair over parameter
pytrees. ``update(grads, state, params) -> (new_params, new_state)``.

* ``sgd``       — (momentum) SGD; the HFL local trainer (paper eq. (1)).
* ``adam``      — AdamW for the D3QN agent and small-model runs.
* ``adafactor`` — factored second moment (Shazeer & Stern); the default
  for >=100B configs in the dry-run: state is ~params bytes/row+col,
  which is what makes 405B fit the 16 GiB/chip budget.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple]


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    gn = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


# -------------------------------------------------------------------- SGD

def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        st = {"step": jnp.zeros((), jnp.int32)}
        if momentum > 0:
            st["mu"] = jax.tree.map(jnp.zeros_like, params)
        return st

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr_fn(step)
        if momentum > 0:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            new_params = jax.tree.map(lambda p, m: p - lr_t * m, params, mu)
            return new_params, {"step": step + 1, "mu": mu}
        new_params = jax.tree.map(lambda p, g: p - lr_t * g, params, grads)
        return new_params, {"step": step + 1}

    return Optimizer(init, update)


# ------------------------------------------------------------------- Adam

def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


# -------------------------------------------------------------- Adafactor

def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored 2nd moment for matrices; full for vectors/scalars."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mom": jax.tree.map(leaf, params,
                                    is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def leaf(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(g.shape):
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g32 * rfac[..., None] * jnp.expand_dims(cfac, -2)
                nst = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                nst = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), nst

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["mom"])
        outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_mom = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_params, {"step": step, "mom": new_mom}

    return Optimizer(init, update)
